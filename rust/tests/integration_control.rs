//! Integration tests over the full deployment: migration (Fig. 8),
//! kill/provision, baseline behaviours, failure injection.

use std::time::Duration;

use nalar::baselines::SystemUnderTest;
use nalar::config::DeploymentConfig;
use nalar::coordinator::PolicyCmd;
use nalar::ids::{InstanceId, NodeId, SessionId};
use nalar::json;
use nalar::server::Deployment;
use nalar::state::ManagedList;
use nalar::workflow::{Env, WorkflowKind};

fn fast(cfg: &mut DeploymentConfig) {
    cfg.time_scale = 0.0005;
    cfg.control.global_period_ms = 10;
}

#[test]
fn migration_moves_queued_session_work() {
    // one slow agent with 2 instances; flood instance 0 via sticky pins,
    // then migrate a session and verify it completes on instance 1.
    let mut cfg = DeploymentConfig::from_json(
        r#"{"agents": [{"name": "a", "kind": "llm", "instances": 2,
             "directives": {"managed_state": true, "max_instances": 2},
             "profile": {"base_s": 0.2, "mean_output_tokens": 200}, "methods": ["m"]}],
            "policies": []}"#,
    )
    .unwrap();
    fast(&mut cfg);
    let d = Deployment::launch(cfg).unwrap();

    // Pin sessions 1..4 to a:0 and enqueue work there.
    let i0 = InstanceId::new("a", 0);
    let i1 = InstanceId::new("a", 1);
    let mut futs = Vec::new();
    for s in 1..=4u64 {
        d.router().pin(SessionId(s), "a", i0.clone());
        let ctx = d.ctx(SessionId(s));
        futs.push(ctx.agent("a").call("m", json!({"prompt": "work", "max_new_tokens": 64})));
    }
    // Migrate session 4 (queued behind the others) to a:1.
    std::thread::sleep(Duration::from_millis(20));
    d.global().apply(vec![PolicyCmd::Migrate {
        session: SessionId(4),
        from: i0.clone(),
        to: i1.clone(),
    }]);

    for f in &futs {
        f.value(Duration::from_secs(20)).unwrap();
    }
    // Fig. 8 step 4: the session's sticky route now points at the target.
    assert_eq!(d.router().sticky_of(SessionId(4), "a"), Some(i1.clone()));
    let view = d.global().collect();
    let m1 = view.instances.iter().find(|i| i.id == i1).unwrap();
    assert!(m1.m.migrated_in >= 1, "target never received the migration");
    d.shutdown();
}

#[test]
fn migration_round_trip_preserves_managed_state_and_kv() {
    // Fig. 8 end-to-end: drive a session on a:0 (node 0), migrate it to
    // a:1 (node 1), and assert that (a) managed state survives and is
    // observable through the directory-aware bind from *any* node, and
    // (b) the engine-side KV cache moved with the session (the follow-up
    // call is a KV hit at the destination, not a recompute).
    let mut cfg = DeploymentConfig::from_json(
        r#"{"nodes": 2,
            "agents": [{"name": "a", "kind": "llm", "instances": 2,
             "directives": {"managed_state": true, "max_instances": 2},
             "profile": {"base_s": 0.1, "mean_output_tokens": 40}, "methods": ["m"]}],
            "policies": []}"#,
    )
    .unwrap();
    fast(&mut cfg);
    let d = Deployment::launch(cfg).unwrap();
    let i0 = InstanceId::new("a", 0); // round-robin placement: a:0 -> node 0
    let i1 = InstanceId::new("a", 1); // a:1 -> node 1
    let session = SessionId(2); // home store = node 0 in a 2-node cluster
    d.router().pin(session, "a", i0.clone());

    // Turn 1: write managed state and warm the KV cache on a:0.
    let env = Env::new(&d, session);
    env.state_list("history").push(json!({"turn": 1}));
    let f = d.ctx(session).agent("a").call("m", json!({"prompt": "warm", "max_new_tokens": 24}));
    assert_eq!(f.value(Duration::from_secs(20)).unwrap().get("kv").as_str(), Some("miss"));

    // MigrateOut -> MigrateIn between the component controllers.
    d.global().apply(vec![PolicyCmd::Migrate { session, from: i0, to: i1.clone() }]);
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        let view = d.global().collect();
        let arrived =
            view.instances.iter().find(|i| i.id == i1).is_some_and(|i| i.m.migrated_in >= 1);
        if arrived || std::time::Instant::now() > deadline {
            assert!(arrived, "migration never reached a:1");
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }

    // (a) Managed state followed the session to node 1: the home store no
    // longer has it, and the directory-aware bind still finds it.
    let home_bind = ManagedList::bind(d.stores().node(NodeId(0)), session, "history");
    assert!(home_bind.is_empty(), "state should have left the home store");
    let env2 = Env::new(&d, session);
    assert_eq!(env2.state_list("history").len(), 1, "state lost in migration");
    env2.state_list("history").push(json!({"turn": 2}));
    assert_eq!(env2.state_list("history").len(), 2, "binds must hit the migrated store");

    // (b) KV bytes moved: the session's next call lands on a:1 (Fig. 8
    // step 4 repinned it) and finds its cache resident.
    assert_eq!(d.router().sticky_of(session, "a"), Some(i1));
    let f2 = d.ctx(session).agent("a").call("m", json!({"prompt": "more", "max_new_tokens": 24}));
    assert_eq!(
        f2.value(Duration::from_secs(20)).unwrap().get("kv").as_str(),
        Some("hit"),
        "KV cache did not survive the migration"
    );
    d.shutdown();
}

#[test]
fn kill_and_provision_lifecycle() {
    let mut cfg = DeploymentConfig::from_json(
        r#"{"agents": [{"name": "a", "kind": "web_search", "instances": 2,
             "directives": {"min_instances": 1, "max_instances": 3},
             "profile": {"base_s": 0.0}, "methods": ["search"]}],
            "policies": []}"#,
    )
    .unwrap();
    fast(&mut cfg);
    let d = Deployment::launch(cfg).unwrap();
    assert_eq!(d.bus().instances_of("a").len(), 2);

    // kill a:1
    d.global().apply(vec![PolicyCmd::Kill(InstanceId::new("a", 1))]);
    let deadline = std::time::Instant::now() + Duration::from_secs(2);
    while d.bus().instances_of("a").len() != 1 && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(d.bus().instances_of("a").len(), 1);

    // provision a new one (gets a fresh index)
    d.global().apply(vec![PolicyCmd::Provision { agent: "a".into() }]);
    let deadline = std::time::Instant::now() + Duration::from_secs(2);
    while d.bus().instances_of("a").len() != 2 && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(d.bus().instances_of("a").len(), 2);

    // calls still served after the churn
    let ctx = d.ctx(d.new_session());
    let f = ctx.agent("a").call("search", json!({"query": "q"}));
    assert!(f.value(Duration::from_secs(5)).is_ok());
    d.shutdown();
}

#[test]
fn provision_respects_max_instances() {
    let mut cfg = DeploymentConfig::from_json(
        r#"{"agents": [{"name": "a", "kind": "web_search", "instances": 1,
             "directives": {"max_instances": 1}, "profile": {"base_s": 0.0},
             "methods": ["search"]}], "policies": []}"#,
    )
    .unwrap();
    fast(&mut cfg);
    let d = Deployment::launch(cfg).unwrap();
    assert!(d.spawn_instance("a").is_err(), "must refuse beyond max_instances");
    assert!(d.spawn_instance("ghost").is_err());
    d.shutdown();
}

#[test]
fn killed_instance_fails_pending_futures_reported_to_driver() {
    let mut cfg = DeploymentConfig::from_json(
        r#"{"agents": [{"name": "a", "kind": "llm", "instances": 1,
             "directives": {"max_instances": 1},
             "profile": {"base_s": 1.0, "mean_output_tokens": 500}, "methods": ["m"]}],
            "policies": []}"#,
    )
    .unwrap();
    fast(&mut cfg);
    let d = Deployment::launch(cfg).unwrap();
    let ctx = d.ctx(d.new_session());
    // enqueue a few; kill the instance while they're pending
    let futs: Vec<_> = (0..3)
        .map(|_| ctx.agent("a").call("m", json!({"prompt": "x", "max_new_tokens": 400})))
        .collect();
    std::thread::sleep(Duration::from_millis(10));
    d.global().apply(vec![PolicyCmd::Kill(InstanceId::new("a", 0))]);
    let mut failures = 0;
    for f in &futs {
        if f.value(Duration::from_secs(3)).is_err() {
            failures += 1;
        }
    }
    assert!(failures >= 1, "paper §5: failures must surface to the driver");
    d.shutdown();
}

#[test]
fn baselines_stay_sticky_nalar_does_not() {
    for (system, expect_sticky) in [
        (SystemUnderTest::CrewLike, true),
        (SystemUnderTest::Nalar, false),
    ] {
        let mut cfg = DeploymentConfig::from_json(
            r#"{"agents": [{"name": "a", "kind": "web_search", "instances": 2,
                 "directives": {"max_instances": 2}, "profile": {"base_s": 0.0},
                 "methods": ["search"]}], "policies": []}"#,
        )
        .unwrap();
        fast(&mut cfg);
        cfg.policies.clear();
        let d = Deployment::launch_as(cfg, system).unwrap();
        let session = d.new_session();
        for _ in 0..3 {
            let ctx = d.ctx(session);
            let f = ctx.agent("a").call("search", json!({"query": "q"}));
            f.value(Duration::from_secs(5)).unwrap();
        }
        let pinned = d.router().sticky_of(session, "a").is_some();
        assert_eq!(pinned, expect_sticky, "{}", system.name());
        d.shutdown();
    }
}

#[test]
fn resource_realloc_provisions_hot_agent_under_imbalance() {
    // chat idle with 2 instances, coder overloaded with 1: the policy
    // should kill a chat instance and provision a coder.
    let mut cfg = DeploymentConfig::from_json(
        r#"{"control": {"global_period_ms": 10},
            "agents": [
              {"name": "chat", "kind": "llm", "instances": 2,
               "directives": {"min_instances": 1, "max_instances": 3},
               "profile": {"base_s": 0.05, "mean_output_tokens": 20}, "methods": ["m"]},
              {"name": "coder", "kind": "llm", "instances": 1,
               "directives": {"min_instances": 1, "max_instances": 3},
               "profile": {"base_s": 0.3, "mean_output_tokens": 300}, "methods": ["m"]}],
            "policies": ["resource_realloc"]}"#,
    )
    .unwrap();
    cfg.time_scale = 0.002;
    let d = Deployment::launch(cfg).unwrap();
    // flood coder
    let ctx = d.ctx(d.new_session());
    let futs: Vec<_> = (0..24)
        .map(|_| ctx.agent("coder").call("m", json!({"prompt": "x", "max_new_tokens": 300})))
        .collect();
    let deadline = std::time::Instant::now() + Duration::from_secs(8);
    let mut reallocated = false;
    while std::time::Instant::now() < deadline {
        if d.bus().instances_of("coder").len() > 1 {
            reallocated = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(reallocated, "resource_realloc never provisioned a coder instance");
    for f in futs {
        let _ = f.value(Duration::from_secs(20));
    }
    d.shutdown();
}
