//! Ingress: the open-loop serving front door.
//!
//! Everything before this subsystem ran workflows *closed-loop*: the
//! harness spawned one caller thread per request and each driver blocked
//! its caller — no queueing, no admission, no way to reproduce the paper's
//! capacity claim ("sustains 80 RPS where baselines fail", §6). Ingress is
//! the missing front of the pipeline:
//!
//! * [`Ingress::submit`] — the single submit entry point, fed by a
//!   [`SubmitRequest`] builder (workflow kind, payload, tenant, session,
//!   deadline, optional custom driver) — accepts a workflow request
//!   asynchronously, stamps its [`RequestId`]/[`SessionId`] at admission,
//!   and enqueues it into a per-workflow bounded queue instead of
//!   blocking the caller — the returned [`Ticket`] is the caller's
//!   completion handle, including mid-flight withdrawal via
//!   [`Ticket::cancel`]. The HTTP serving plane
//!   ([`crate::server::http`]) maps wire requests 1:1 onto this call.
//! * an [`AdmissionController`] per queue decides accept-vs-shed
//!   ([`AdmissionPolicy`]: unbounded / bounded / token bucket); shed
//!   requests fail fast with a retryable [`Error::Shed`].
//! * the front door is **multi-tenant** ([`fairness`], config
//!   `ingress.tenants`): every request is stamped with a
//!   [`TenantId`] at admission ([`SubmitRequest::tenant`]), each tenant may
//!   carry its own token bucket *under* the shared admission policy, and
//!   each workflow queue splits into per-tenant sub-queues served by
//!   deficit round robin — weighted-fair across tenants, while *inside* a
//!   tenant's sub-queue the configured [`SchedulePolicy`] still orders
//!   requests (fairness composes with SRTF, it does not replace it).
//! * an **event-driven scheduler** multiplexes admitted requests over a
//!   small fixed thread pool: each request is a resumable
//!   [`crate::workflow::Driver`] polled until it suspends, then *parked*
//!   in an in-flight table — occupying no thread — until a
//!   [`crate::futures::FutureCell`] waker pushes it back onto the ready
//!   queue. `ingress.workers` bounds *threads*; `ingress.max_in_flight`
//!   bounds concurrent requests (the multiplexing factor in-flight ÷
//!   threads is published as telemetry). Deadlines are enforced on parked
//!   and queued work by a periodic sweep, again without a thread per
//!   request.
//! * queue pops are **policy-ordered** ([`schedule`], config
//!   `ingress.schedule`): FIFO, deadline slack (SRTF at the front door —
//!   pop the request whose deadline minus estimated remaining work is
//!   tightest) or stage (drain later-stage work first).
//! * queue depth and accept/shed/complete/cancel counters are pushed into
//!   the node store (`ingress/{workflow}`), where
//!   [`crate::coordinator::GlobalController::collect`] aggregates them so
//!   overload-aware policies (e.g.
//!   [`crate::coordinator::policies::OverloadProvision`]) can react.
//! * every lifecycle transition is **traced** ([`crate::trace`]): the
//!   scheduler records admitted / queued / scheduled / polling / parked /
//!   resumed / terminal events into a bounded flight recorder
//!   (`trace.capacity`, [`SchedulerOpts::trace`]), and each completed
//!   request's per-stage decomposition — queue-wait, sched-delay,
//!   poll-time, future-wait, engine-service — folds into per-(workflow,
//!   tenant) log-bucket histograms surfaced through
//!   [`IngressMetrics::breakdown`], so policies see *queueing delay*,
//!   not just depth (DESIGN.md §10).
//!
//! **Request lifecycle.** A ticket observes exactly one terminal outcome,
//! however the race between completion, deadline expiry and cancellation
//! lands (see DESIGN.md §7 for the state machine):
//!
//! ```text
//! submitted ──► queued ──► polling ◄──► parked
//!                 │           │            │
//!                 ▼           ▼            ▼
//!          {expired_in_queue, done, failed, expired, cancelled}
//! ```
//!
//! [`loadgen`] drives this front door with a Poisson arrival process to
//! produce the `BENCH_rps_sweep.json` saturation curve.

pub mod admission;
pub mod fairness;
pub mod loadgen;
pub mod routing;
pub mod schedule;

pub use admission::{AdmissionController, AdmissionPolicy};
pub use fairness::Drr;
pub use routing::{RouteMode, RouteState, SharedRoute};
pub use schedule::SchedulePolicy;

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, Weak};
use std::time::{Duration, Instant};

use crate::coordinator::{IngressMetrics, TenantMetrics};
use crate::error::{Error, Result};
use crate::futures::{FutureCell, Value};
use crate::ids::{NodeId, RequestId, SessionId, TenantId};
use crate::journal::{self, JournalSink, RecoveryPlan};
use crate::metrics::{merge_breakdowns, Histogram, HistogramSnapshot, StageHistograms};
use crate::nodestore::keys;
use crate::server::Deployment;
use crate::trace::{TraceKind, TraceSink};
use crate::util::clock::Clock;
use crate::workflow::{driver_for, restore_driver, Driver, Env, Step, WorkflowKind};

use routing::RouteHint;
use schedule::{pick, Key, StageStats};

/// Completion slot shared between a [`Ticket`] and the scheduler.
struct TicketCell {
    slot: Mutex<TicketState>,
    cv: Condvar,
}

struct TicketState {
    done: bool,
    result: Option<Result<Value>>,
    /// Submit-to-completion latency, set exactly once at fulfilment.
    latency: Option<Duration>,
}

impl TicketCell {
    fn new() -> Arc<TicketCell> {
        Arc::new(TicketCell {
            slot: Mutex::new(TicketState { done: false, result: None, latency: None }),
            cv: Condvar::new(),
        })
    }

    /// Install the terminal outcome. Returns true iff *this* call won:
    /// completion, deadline expiry and cancellation may race, and whoever
    /// loses must not double-count — the ticket has exactly one terminal
    /// state and the counters agree with it.
    fn fulfil(&self, result: Result<Value>, latency: Duration) -> bool {
        let mut g = self.slot.lock().unwrap();
        let first = !g.done;
        if first {
            g.done = true;
            g.result = Some(result);
            g.latency = Some(latency);
        }
        drop(g);
        self.cv.notify_all();
        first
    }
}

/// Everything one front-door submission carries, as a builder — the
/// consolidated submit surface (this replaced the four-way
/// `submit`/`submit_with`/`submit_driver`/`submit_driver_with` split).
/// Construct with [`SubmitRequest::workflow`], chain what the request
/// needs, hand it to [`Ingress::submit`]:
///
/// ```ignore
/// let ticket = ingress.submit(
///     SubmitRequest::workflow(WorkflowKind::Router)
///         .input(json!({"prompt": "hi"}))
///         .tenant("meek")
///         .deadline(Duration::from_secs(30)),
/// )?;
/// ```
///
/// The HTTP front door builds one of these per wire request
/// (`X-Nalar-Tenant` → [`Self::tenant`], `X-Nalar-Deadline-Ms` →
/// [`Self::deadline`], the POST body → [`Self::input`]).
pub struct SubmitRequest {
    kind: WorkflowKind,
    input: Value,
    driver: Option<Box<dyn Driver>>,
    session: Option<SessionId>,
    tenant: Option<String>,
    timeout: Duration,
    retain_trace: bool,
}

impl SubmitRequest {
    /// Default end-to-end deadline when [`Self::deadline`] is not called.
    pub const DEFAULT_DEADLINE: Duration = Duration::from_secs(30);

    /// A submission for `kind` with `Null` input, a fresh session, the
    /// default tenant and [`Self::DEFAULT_DEADLINE`].
    pub fn workflow(kind: WorkflowKind) -> SubmitRequest {
        SubmitRequest {
            kind,
            input: Value::Null,
            driver: None,
            session: None,
            tenant: None,
            timeout: Self::DEFAULT_DEADLINE,
            retain_trace: false,
        }
    }

    /// Workflow payload (what [`crate::workflow::driver_for`] builds the
    /// standard driver from). Ignored when [`Self::driver`] supplies a
    /// custom one.
    pub fn input(mut self, input: Value) -> Self {
        self.input = input;
        self
    }

    /// Run a caller-built resumable [`Driver`] instead of the workflow's
    /// standard one — the serving-side analog of "drivers are ordinary
    /// code": any state machine can be admitted, scheduled, expired and
    /// cancelled like the built-ins. (The deterministic scheduler tests
    /// inject [`crate::testkit::ScriptedEngine`] drivers through this.)
    pub fn driver(mut self, driver: Box<dyn Driver>) -> Self {
        self.driver = Some(driver);
        self
    }

    /// Continue an existing session (default: open a fresh one).
    pub fn session(mut self, session: SessionId) -> Self {
        self.session = Some(session);
        self
    }

    /// Charge the request to the named tenant: its token bucket admits,
    /// its DRR sub-queue holds the request, its counters take the
    /// outcome. `None`/unset = the deployment's first configured tenant
    /// (the implicit `default` when no `ingress.tenants` block exists).
    /// Unknown names are a config error when tenants are configured — a
    /// typo must not silently share someone else's bucket; with the
    /// implicit single-tenant table every name collapses onto it (there
    /// is no tenancy to enforce — this is also how baselines stay
    /// single-tenant after `baselines::SystemUnderTest::apply`).
    pub fn tenant(mut self, name: impl Into<String>) -> Self {
        self.tenant = Some(name.into());
        self
    }

    /// End-to-end deadline, counted from admission.
    pub fn deadline(mut self, timeout: Duration) -> Self {
        self.timeout = timeout;
        self
    }

    /// Keep the request's flight-recorder timeline past its terminal
    /// outcome. Default off: in-proc submits evict their timeline as soon
    /// as the terminal event is recorded (after the histogram fold), so
    /// normal local churn never rolls the bounded ring — only consumers
    /// with a later read of the timeline (the HTTP plane, which evicts on
    /// registry consumption instead; `nalar trace`) opt in.
    pub fn retain_trace(mut self) -> Self {
        self.retain_trace = true;
        self
    }
}

/// The caller's handle for an admitted request. `submit` returns it
/// immediately; the request runs whenever the scheduler picks it up.
pub struct Ticket {
    pub request: RequestId,
    pub session: SessionId,
    /// Tenant the request was charged to, stamped at admission.
    pub tenant: TenantId,
    cell: Arc<TicketCell>,
    /// Workflow shard index: `cancel` keys into the owning scheduler
    /// lock domain by `(idx, request)` — no global request→shard map.
    idx: usize,
    /// Back-reference to the scheduler (weak: a ticket outliving its
    /// ingress must not keep the scheduler alive, and `cancel` on a dead
    /// ingress is a no-op).
    inner: Weak<IngressInner>,
}

impl Ticket {
    /// Block until the request finishes or `timeout` passes. Consumes the
    /// result: a second `wait` after a successful one errors.
    pub fn wait(&self, timeout: Duration) -> Result<Value> {
        let deadline = Instant::now() + timeout;
        let mut g = self.cell.slot.lock().unwrap();
        loop {
            if g.done {
                return g
                    .result
                    .take()
                    .unwrap_or_else(|| Err(Error::Msg("ticket result already taken".into())));
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(Error::Deadline(timeout));
            }
            let (g2, _) = self.cell.cv.wait_timeout(g, deadline - now).unwrap();
            g = g2;
        }
    }

    /// Submit-to-completion latency, once the request finished.
    pub fn latency(&self) -> Option<Duration> {
        self.cell.slot.lock().unwrap().latency
    }

    /// Non-blocking probe: `None` while the request is still live,
    /// `Some(result)` once a terminal outcome landed. Consumes the result
    /// exactly like [`Self::wait`] (the HTTP front door polls parked
    /// requests through this on `GET /v1/requests/{id}`).
    pub fn try_take(&self) -> Option<Result<Value>> {
        let mut g = self.cell.slot.lock().unwrap();
        if !g.done {
            return None;
        }
        Some(
            g.result
                .take()
                .unwrap_or_else(|| Err(Error::Msg("ticket result already taken".into()))),
        )
    }

    /// Withdraw the request: atomically remove it from whichever
    /// scheduler table holds it (admission queue, ready queue or
    /// parked-continuation table), fail its outstanding futures, and
    /// fulfil the ticket with the non-retryable [`Error::Cancelled`].
    ///
    /// Returns true if the cancellation was *delivered* — the request was
    /// still live somewhere. Delivery racing a concurrent completion or
    /// deadline expiry is resolved by table ownership: exactly one
    /// terminal outcome ever lands on the ticket (read it from
    /// [`Self::wait`]). A cancel after the request finished (or a second
    /// cancel) returns false and changes nothing. Agent calls already
    /// executing on an engine are not interrupted — their futures are
    /// failed so nothing consumes them, and their late results are
    /// dropped (§5: report, don't mask).
    pub fn cancel(&self) -> bool {
        match self.inner.upgrade() {
            Some(inner) => inner.cancel(self.idx, self.request),
            None => false,
        }
    }
}

/// One admitted request waiting to start (driver not yet built, unless
/// the caller handed one in via [`SubmitRequest::driver`]).
struct Queued {
    session: SessionId,
    request: RequestId,
    /// Tenant index (into `IngressInner::tenants`) the request is charged
    /// to — the sub-queue it waits in and the counters its outcome lands
    /// on.
    tenant: usize,
    input: Value,
    driver: Option<Box<dyn Driver>>,
    submitted: Instant,
    deadline: Instant,
    timeout: Duration,
    cell: Arc<TicketCell>,
    /// See [`SubmitRequest::retain_trace`] — carried to the terminal
    /// path, which evicts the timeline unless set.
    retain_trace: bool,
}

/// One started request: a stored continuation, not a thread's stack. This
/// is the representation the two-level control plane needs for everything
/// downstream — it can be parked, re-enqueued, expired, cancelled or
/// (eventually) migrated, all without owning a thread.
struct InFlight {
    idx: usize,
    request: RequestId,
    /// Tenant index — outcome counters are per (workflow, tenant).
    tenant: usize,
    driver: Box<dyn Driver>,
    env: Env,
    /// The request's JIT-routing hint (`None` when routing is off). The
    /// decision point in `run_poll` refreshes it against the current
    /// deadline slack before every poll; the agent stubs (and the
    /// scripted testkit engine) consume it once per issued call.
    hint: Option<Arc<RouteHint>>,
    submitted: Instant,
    deadline: Instant,
    timeout: Duration,
    cell: Arc<TicketCell>,
    /// See [`SubmitRequest::retain_trace`].
    retain_trace: bool,
    /// Futures this request already holds a waker on: each is subscribed
    /// at most once per request, so a join pending through many wake
    /// cycles doesn't accumulate duplicate wakers (and their spurious
    /// re-polls) on its slowest futures.
    subscribed: HashSet<u64>,
    /// Deepest stage the driver has reported ([`Driver::stage`]) — the
    /// scheduling key for `stage` ordering and the lookup key for the
    /// `deadline_slack` remaining-work estimate.
    stage: u32,
    /// When the request entered each stage; folded into [`StageStats`]
    /// at (successful) completion.
    stage_entered: Vec<(u32, Instant)>,
    /// Per-stage latency accumulators (DESIGN.md §10), maintained at the
    /// same transitions the trace events mark so the decomposition is
    /// exact on a virtual clock: submit→scheduled, time spent runnable
    /// in the ready queue, time inside `Driver::poll`, and time parked
    /// on future wakers. `queue_wait + sched_delay + poll_time +
    /// future_wait` = end-to-end latency up to clock granularity.
    queue_wait: Duration,
    sched_delay: Duration,
    poll_time: Duration,
    future_wait: Duration,
    /// When this continuation entered the ready queue (drained into
    /// `sched_delay` on pop).
    ready_since: Option<Instant>,
    /// When this continuation parked (drained into `future_wait` on
    /// wake/nudge).
    parked_at: Option<Instant>,
}

/// A request whose deadline expired before completion, collected by the
/// sweep for fulfilment outside the scheduler lock.
struct Lapsed {
    idx: usize,
    /// Tenant index the expiry is charged to.
    tenant: usize,
    submitted: Instant,
    timeout: Duration,
    cell: Arc<TicketCell>,
    request: RequestId,
    /// See [`SubmitRequest::retain_trace`].
    retain_trace: bool,
    /// True if the request had started (a driver ran and may have
    /// outstanding futures to bulk-fail); false for in-queue expiries,
    /// which never issued a call.
    started: bool,
    /// Stage-entry instants carried over from the in-flight entry (empty
    /// for in-queue expiries): stages the request *exited* before dying
    /// still feed [`StageStats`] — see `fold_censored_stages`.
    stage_entered: Vec<(u32, Instant)>,
}

/// Scheduler state for ONE workflow entry — its own lock domain (a
/// "shard"). Submits, wakeups, pops, cancels and mid-poll race
/// resolution for different workflows touch different shards and never
/// contend; only `stop` and the deadline sweep visit every shard, and
/// they take the locks one at a time (never two shard locks at once, so
/// there is no lock-ordering hazard). Within one shard the semantics are
/// identical to the old single-lock scheduler — which is what keeps the
/// deterministic fairness/ordering suites passing unchanged. Cross-shard
/// gauges (`depth`, `in_flight`) live as atomics on [`IngressInner`] so
/// the metrics read path never touches a shard lock (DESIGN.md §11).
struct ShardState {
    /// Admission sub-queues, one per tenant, served weighted-fair by
    /// `drr`.
    queues: Vec<VecDeque<Queued>>,
    /// Deficit-round-robin state over the tenant sub-queues.
    drr: Drr,
    /// Runnable continuations (woken or freshly admitted). Pop order is
    /// the configured [`SchedulePolicy`], not necessarily front-first.
    ready: VecDeque<InFlight>,
    /// Suspended continuations keyed by `RequestId.0`, waiting on wakers.
    parked: HashMap<u64, InFlight>,
    /// Wakeups that arrived while their request was being polled (it was
    /// neither parked nor ready); consumed when the poll finishes.
    woken: HashSet<u64>,
    /// Cancellations that arrived while their request was being polled —
    /// the only moment a request is in no table. Consumed when the poll
    /// finishes: a `Pending` result turns into the cancel outcome
    /// instead of parking; a `Done` result means completion won the race.
    cancelled: HashSet<u64>,
    /// Parked continuations with nothing to subscribe to (a
    /// shouldn't-happen): the next sweep re-polls them — a bounded 0..5ms
    /// backoff instead of a hot requeue loop.
    nudge: Vec<u64>,
    /// Every started-but-unfinished request id of this workflow (ready +
    /// parked + polling). Wakers and cancels key into the owning shard by
    /// `(workflow index, RequestId)` — both are carried by the
    /// [`Ticket`] and the waker closure, so no global request→shard map
    /// exists anywhere.
    live: HashSet<u64>,
}

/// Which hot-path operation a shard-lock acquisition serves — the key
/// the contention bench's critical-section hold-time histograms are
/// split by (`nalar bench contention`).
#[derive(Clone, Copy, Debug)]
pub enum HoldOp {
    Submit,
    Wake,
    Poll,
    Complete,
    Sweep,
}

/// Per-op shard-lock hold-time histograms, recorded in microseconds by
/// [`HoldGuard`] on drop. Only installed by the contention bench (via
/// [`SchedulerOpts::hold`]); in production the slot is `None` and the
/// only hot-path cost is one `Option` check per lock acquisition.
pub struct HoldStats {
    submit: Histogram,
    wake: Histogram,
    poll: Histogram,
    complete: Histogram,
    sweep: Histogram,
}

impl HoldStats {
    pub fn new() -> Arc<HoldStats> {
        Arc::new(HoldStats {
            submit: Histogram::new(),
            wake: Histogram::new(),
            poll: Histogram::new(),
            complete: Histogram::new(),
            sweep: Histogram::new(),
        })
    }

    fn hist(&self, op: HoldOp) -> &Histogram {
        match op {
            HoldOp::Submit => &self.submit,
            HoldOp::Wake => &self.wake,
            HoldOp::Poll => &self.poll,
            HoldOp::Complete => &self.complete,
            HoldOp::Sweep => &self.sweep,
        }
    }

    /// Snapshot one op's hold-time histogram. Samples are recorded in
    /// microseconds (the histogram's native 1e-6..1123 range then spans
    /// sub-ns..1.1ms holds), so `quantile(q) * 1000.0` is nanoseconds.
    pub fn snapshot(&self, op: HoldOp) -> HistogramSnapshot {
        self.hist(op).snapshot()
    }
}

impl std::fmt::Debug for HoldStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("HoldStats")
    }
}

/// A locked scheduler shard. When hold-time instrumentation is installed
/// the acquisition instant is stamped here and the critical-section
/// duration recorded on drop — measuring *hold* time (what other threads
/// would wait behind), not acquisition wait.
struct HoldGuard<'a> {
    g: MutexGuard<'a, ShardState>,
    since: Option<(Instant, HoldOp, &'a HoldStats)>,
}

impl std::ops::Deref for HoldGuard<'_> {
    type Target = ShardState;
    fn deref(&self) -> &ShardState {
        &self.g
    }
}

impl std::ops::DerefMut for HoldGuard<'_> {
    fn deref_mut(&mut self) -> &mut ShardState {
        &mut self.g
    }
}

impl Drop for HoldGuard<'_> {
    fn drop(&mut self) {
        if let Some((t0, op, h)) = self.since.take() {
            h.hist(op).record(t0.elapsed().as_secs_f64() * 1e6);
        }
    }
}

/// What one scheduler iteration decided to do.
enum Task {
    /// Re-poll a woken continuation.
    Poll(InFlight),
    /// Start a freshly admitted request (build its driver, first poll).
    Admit(usize, Queued),
}

/// Sizing + policy for the event-driven scheduler.
#[derive(Debug, Clone)]
pub struct SchedulerOpts {
    /// OS threads multiplexing the in-flight table.
    pub workers: usize,
    /// Concurrent started requests (the backpressure bound: admission
    /// queues only drain while in-flight is below this).
    pub max_in_flight: usize,
    /// Queue-pop ordering override; `None` = the deployment config's
    /// `ingress.schedule`.
    pub schedule: Option<SchedulePolicy>,
    /// Time source. Production uses the wall clock; deterministic
    /// scheduler tests inject [`crate::testkit::Clock::manual`] so
    /// deadlines and sweeps are driven by `advance()`, not `sleep()`.
    pub clock: Clock,
    /// Trace sink override; `None` = build a fresh flight recorder sized
    /// by the deployment config's `ingress.trace.capacity` (0 disables
    /// tracing) on [`Self::clock`]. Timelines recorded on a virtual
    /// clock are fully deterministic.
    pub trace: Option<TraceSink>,
    /// Shard-lock hold-time instrumentation (`nalar bench contention`).
    /// `None` (the default, and production) records nothing.
    pub hold: Option<Arc<HoldStats>>,
    /// Durable request journal ([`crate::journal`]); disabled by default.
    /// [`Ingress::start`] opens it from `ingress.journal.path` and
    /// replays the existing log before serving.
    pub journal: JournalSink,
}

impl SchedulerOpts {
    pub fn new(workers: usize, max_in_flight: usize) -> SchedulerOpts {
        SchedulerOpts {
            workers,
            max_in_flight,
            schedule: None,
            clock: Clock::wall(),
            trace: None,
            hold: None,
            journal: JournalSink::disabled(),
        }
    }
}

/// Telemetry publish throttle — same cadence as the component
/// controllers' `maybe_push_telemetry`, so the hot path pays at most one
/// store write per queue per period instead of one per event.
const PUBLISH_PERIOD: Duration = Duration::from_millis(20);

/// Deadline-sweep cadence: bounds how stale an expired parked/queued
/// request can get before it is failed fast. Also the idle wait, so a
/// missed notify never stalls the pool longer than this.
const SWEEP_PERIOD: Duration = Duration::from_millis(5);

/// One tenant of the front door (resolved from `ingress.tenants`, or the
/// implicit single `default`).
struct TenantSpec {
    name: String,
    weight: f64,
}

struct IngressInner {
    d: Deployment,
    kinds: Vec<WorkflowKind>,
    /// Tenant table shared by every workflow queue. Index = `TenantId`.
    tenants: Vec<TenantSpec>,
    /// Whether the deployment actually configured `ingress.tenants`
    /// (false = the implicit single-tenant table, where any submitted
    /// tenant name collapses onto it instead of erroring).
    tenants_configured: bool,
    /// One scheduler lock domain per entry of `kinds` (see
    /// [`ShardState`]). Always acquired through [`Self::lock_shard`].
    shards: Vec<Mutex<ShardState>>,
    /// Event-sequence counter paired with `cv` for idle parking. Workers
    /// read it before scanning the shards and wait only if it is
    /// unchanged when they take this mutex again; every notifier bumps it
    /// under the mutex first — so a submit/wake/completion landing
    /// between a worker's scan and its wait is never a lost wakeup (the
    /// single-lock scheduler got this for free by waiting on the same
    /// mutex everything else took).
    events: Mutex<u64>,
    cv: Condvar,
    /// Shared per-workflow admission policy (the bounded cap / workflow
    /// token bucket). Decision-only: accept/shed are counted on the
    /// per-tenant controllers below, exactly once per submit.
    admission: Vec<AdmissionController>,
    /// Per-tenant admission layer under the shared policy:
    /// `tenant_adm[workflow][tenant]` — a token bucket when the tenant
    /// configures a rate, otherwise pass-through. Also the authoritative
    /// accepted/shed counters (the aggregate is their sum).
    tenant_adm: Vec<Vec<AdmissionController>>,
    /// Outcome counters per (workflow, tenant); the per-workflow
    /// aggregates the sweep schema reports are their sums.
    completed: Vec<Vec<AtomicU64>>,
    failed: Vec<Vec<AtomicU64>>,
    /// Deadline expiries that never started a driver (satellite metric:
    /// distinguishable from execution failures in the sweep schema).
    expired_in_queue: Vec<Vec<AtomicU64>>,
    /// Requests withdrawn via [`Ticket::cancel`] before any other
    /// terminal outcome landed.
    cancelled: Vec<Vec<AtomicU64>>,
    /// Per-workflow per-stage time-to-completion EWMAs — the
    /// `deadline_slack` policy's remaining-work estimate. Locked after
    /// the owning shard when both are needed (never the other way
    /// around).
    stage_stats: Vec<Mutex<StageStats>>,
    /// The JIT router (`None` = routing off: no variants declared, or
    /// `ingress.route = "fixed"`) — also installed into the deployment's
    /// [`SharedRoute`] slot at start so the global and component
    /// controllers operate on the same instance.
    route: Option<Arc<RouteState>>,
    /// Per-(workflow, tenant) per-variant dispatch counters. Each
    /// request's [`RouteHint`] holds its row's `Arc` (consumption bumps
    /// it); the metrics snapshot reads them lock-free. Rows are empty
    /// vectors when routing is off.
    routed: Vec<Vec<Arc<Vec<AtomicU64>>>>,
    /// Per-(workflow, tenant) latency-decomposition histograms: completed
    /// requests fold their queue-wait / sched-delay / poll-time /
    /// future-wait / engine-service durations here (lock-free relaxed
    /// increments; [`crate::metrics::Histogram`]).
    breakdown: Vec<Vec<StageHistograms>>,
    /// The flight recorder every lifecycle transition writes into
    /// (disabled = every record is a no-op branch).
    trace: TraceSink,
    schedule: SchedulePolicy,
    clock: Clock,
    workers: usize,
    max_in_flight: usize,
    /// Queued-request count per (workflow, tenant). Mutated only while
    /// holding the owning shard's lock (so the bounded-cap admission
    /// check stays exact), but *read* lock-free by the metrics path —
    /// `snapshot`, `publish`, `GET /metrics`, `depth()` never take a
    /// shard lock.
    depth_gauge: Vec<Vec<AtomicUsize>>,
    /// Started-but-unfinished count per workflow (the `in_flight` gauge),
    /// same mutate-under-shard-lock / read-lock-free discipline.
    in_flight_gauge: Vec<AtomicUsize>,
    /// Started-but-unfinished requests across all shards. The
    /// `max_in_flight` bound is enforced by CAS reservation
    /// ([`Self::try_reserve_total`]) so it is exact even though no global
    /// lock exists any more.
    total_in_flight: AtomicUsize,
    /// Epoch all monotonic-nanos atomics below count from (`clock.now()`
    /// at construction — the scheduler's clock, so virtual-clock tests
    /// drive these through `advance()` exactly like deadlines).
    epoch: Instant,
    /// Next deadline sweep, as nanos since `epoch`. A worker claims a due
    /// sweep by CAS — exactly one runs it.
    next_sweep: AtomicU64,
    /// Per-workflow publish throttle, as nanos since `epoch`, advanced by
    /// CAS — exactly one racing publisher wins each [`PUBLISH_PERIOD`].
    last_publish: Vec<AtomicU64>,
    /// Shard-lock hold-time instrumentation (bench-only; `None` in
    /// production).
    hold: Option<Arc<HoldStats>>,
    /// Durable request journal every lifecycle transition appends to
    /// (disabled = one enum-discriminant branch per site). Emission
    /// sites mirror the trace sink's; DESIGN.md §12 has the taxonomy.
    journal: JournalSink,
    stop: AtomicBool,
}

impl IngressInner {
    fn kind_index(&self, kind: WorkflowKind) -> Option<usize> {
        self.kinds.iter().position(|k| *k == kind)
    }

    /// Submit-to-now on the scheduler's clock (virtual in tests).
    fn since(&self, submitted: Instant) -> Duration {
        self.clock.now().saturating_duration_since(submitted)
    }

    /// Acquire workflow `idx`'s shard lock, tagged with the hot-path op
    /// it serves so the contention bench can split hold times per op.
    fn lock_shard(&self, idx: usize, op: HoldOp) -> HoldGuard<'_> {
        let g = self.shards[idx].lock().unwrap();
        let since = self.hold.as_deref().map(|h| (Instant::now(), op, h));
        HoldGuard { g, since }
    }

    /// Signal the worker pool that new work (or capacity) exists: bump
    /// the event sequence under its mutex, then notify. See
    /// `IngressInner::events` for why the bump must happen under the
    /// mutex.
    fn notify(&self, all: bool) {
        *self.events.lock().unwrap() += 1;
        if all {
            self.cv.notify_all();
        } else {
            self.cv.notify_one();
        }
    }

    /// Total queued requests of one workflow (across its tenant
    /// sub-queues) — the depth the shared admission cap bounds. Lock-free
    /// (the gauges are only mutated under the owning shard's lock, so the
    /// admission check — which holds that lock — still sees an exact
    /// value).
    fn depth_of(&self, idx: usize) -> usize {
        self.depth_gauge[idx].iter().map(|g| g.load(Ordering::Relaxed)).sum()
    }

    /// Reserve one global in-flight slot if the pool is below
    /// `max_in_flight`. CAS keeps the bound exact: two workers racing the
    /// last slot cannot both win it.
    fn try_reserve_total(&self) -> bool {
        self.total_in_flight
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |n| {
                (n < self.max_in_flight).then_some(n + 1)
            })
            .is_ok()
    }

    fn release_total(&self) {
        self.total_in_flight.fetch_sub(1, Ordering::Relaxed);
    }

    /// A started request of workflow `idx` reached a terminal outcome:
    /// free its in-flight slot and drop the workflow gauge. Called before
    /// the ticket is fulfilled, so a caller returning from `wait()`
    /// observes the gauges already settled.
    fn drop_in_flight(&self, idx: usize) {
        self.in_flight_gauge[idx].fetch_sub(1, Ordering::Relaxed);
        self.release_total();
    }

    /// Claim the deadline sweep if it is due; the CAS guarantees exactly
    /// one worker runs each due sweep.
    fn try_claim_sweep(&self) -> bool {
        let now_ns = self.clock.nanos_since(self.epoch);
        let due = self.next_sweep.load(Ordering::Relaxed);
        now_ns >= due
            && self
                .next_sweep
                .compare_exchange(
                    due,
                    now_ns + SWEEP_PERIOD.as_nanos() as u64,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                )
                .is_ok()
    }

    /// Resolve a submitted tenant name to its table index. `None` = the
    /// first tenant; unknown names error on a configured table and
    /// collapse onto the implicit single `default` otherwise (see
    /// [`SubmitRequest::tenant`]).
    fn tenant_index(&self, name: Option<&str>) -> Result<usize> {
        let Some(name) = name else { return Ok(0) };
        if !self.tenants_configured {
            return Ok(0);
        }
        self.tenants.iter().position(|t| t.name == name).ok_or_else(|| {
            Error::Config(format!(
                "unknown tenant `{name}` (known: {})",
                self.tenants.iter().map(|t| t.name.as_str()).collect::<Vec<_>>().join(", ")
            ))
        })
    }

    /// One queue's telemetry snapshot (shared by [`Ingress::metrics`] and
    /// the node-store publish path — one construction site). The
    /// aggregate counters are the sums of the per-tenant split, so the
    /// pre-tenancy schema fields keep their exact meaning.
    fn snapshot(&self, idx: usize) -> IngressMetrics {
        let adm = &self.admission[idx];
        // The whole metrics read path — this fn, `publish`, HTTP
        // `GET /metrics`, `ClusterView::collect`, `depth`, `in_flight` —
        // reads monotonic atomics and lock-free histogram snapshots
        // only. A shard lock held arbitrarily long by a busy scheduler
        // must never stall telemetry (enforced by the
        // `metrics_read_path_never_takes_a_shard_lock` test).
        let tenant_depths: Vec<usize> =
            self.depth_gauge[idx].iter().map(|g| g.load(Ordering::Relaxed)).collect();
        let in_flight = self.in_flight_gauge[idx].load(Ordering::Relaxed);
        let tenants: Vec<TenantMetrics> = self
            .tenants
            .iter()
            .enumerate()
            .map(|(t, spec)| TenantMetrics {
                tenant: spec.name.clone(),
                weight: spec.weight,
                depth: tenant_depths[t],
                accepted: self.tenant_adm[idx][t].accepted.load(Ordering::Relaxed),
                shed: self.tenant_adm[idx][t].shed.load(Ordering::Relaxed),
                completed: self.completed[idx][t].load(Ordering::Relaxed),
                failed: self.failed[idx][t].load(Ordering::Relaxed),
                expired_in_queue: self.expired_in_queue[idx][t].load(Ordering::Relaxed),
                cancelled: self.cancelled[idx][t].load(Ordering::Relaxed),
                variants: self.variant_counts(idx, t),
                breakdown: self.breakdown[idx][t].breakdown(),
            })
            .collect();
        // Aggregate breakdown: merged bucket-wise from the per-tenant
        // histograms (exact — the bucket layout is shared), not an
        // average of quantiles.
        let parts: Vec<_> = self.breakdown[idx].iter().map(|h| h.snapshots()).collect();
        // Aggregate per-variant dispatches = the tenant sum, like every
        // other counter (empty when routing is off).
        let mut variants: Vec<(String, u64)> = self
            .route
            .as_ref()
            .map(|rs| rs.variants().iter().map(|v| (v.name.clone(), 0)).collect())
            .unwrap_or_default();
        for t in &tenants {
            for (agg, (_, n)) in variants.iter_mut().zip(&t.variants) {
                agg.1 += *n;
            }
        }
        IngressMetrics {
            workflow: self.kinds[idx].name().to_string(),
            depth: tenant_depths.iter().sum(),
            in_flight,
            workers: self.workers,
            cap: adm.policy().cap(),
            policy: adm.policy().name().to_string(),
            schedule: self.schedule.name().to_string(),
            accepted: tenants.iter().map(|t| t.accepted).sum(),
            shed: tenants.iter().map(|t| t.shed).sum(),
            completed: tenants.iter().map(|t| t.completed).sum(),
            failed: tenants.iter().map(|t| t.failed).sum(),
            expired_in_queue: tenants.iter().map(|t| t.expired_in_queue).sum(),
            cancelled: tenants.iter().map(|t| t.cancelled).sum(),
            route: self.route.as_ref().map_or_else(|| "fixed".into(), |r| r.mode().name()),
            variants,
            tenants,
            breakdown: merge_breakdowns(&parts),
            trace_dropped: self.trace.dropped(),
        }
    }

    /// Per-variant dispatch counts of one (workflow, tenant) row, in
    /// variant declaration order — empty when routing is off. Lock-free
    /// (metrics read path).
    fn variant_counts(&self, idx: usize, tenant: usize) -> Vec<(String, u64)> {
        let Some(rs) = &self.route else { return Vec::new() };
        rs.variants()
            .iter()
            .zip(self.routed[idx][tenant].iter())
            .map(|(v, c)| (v.name.clone(), c.load(Ordering::Relaxed)))
            .collect()
    }

    /// Push this queue's telemetry into the node store (node 0 hosts the
    /// front door — it is "the" ingress node of the emulated cluster).
    fn publish(&self, idx: usize) {
        let m = self.snapshot(idx);
        let key = keys::ingress(&m.workflow);
        self.d.stores().node(NodeId(0)).put(&key, m);
    }

    /// Throttled [`Self::publish`]: at most one store write per queue per
    /// [`PUBLISH_PERIOD`]. Lifecycle edges (start/stop) publish directly.
    /// Lock-free: a monotonic-nanos compare-and-swap on the scheduler's
    /// clock — exactly one racing publisher wins each period, losers pay
    /// one atomic load. Virtual-clock tests drive the throttle through
    /// `advance()` like every other timer.
    fn maybe_publish(&self, idx: usize) {
        let now_ns = self.clock.nanos_since(self.epoch);
        let last = self.last_publish[idx].load(Ordering::Relaxed);
        if now_ns.saturating_sub(last) < PUBLISH_PERIOD.as_nanos() as u64 {
            return;
        }
        if self.last_publish[idx]
            .compare_exchange(last, now_ns, Ordering::Relaxed, Ordering::Relaxed)
            .is_ok()
        {
            self.publish(idx);
        }
    }

    /// Pop the next ready continuation per the scheduling policy. The
    /// slack estimate is re-read against the current `now` on every pop —
    /// pushed-time priorities would go stale while a request sat ready.
    fn pop_ready(&self, s: &mut ShardState, idx: usize, now: Instant) -> Option<InFlight> {
        if s.ready.is_empty() {
            return None;
        }
        let chosen = pick(
            self.schedule,
            now,
            s.ready.iter().map(|f| Key {
                deadline: f.deadline,
                stage: f.stage,
                est_remaining: self.stage_stats[idx].lock().unwrap().estimate(f.stage),
            }),
        )?;
        let mut f = s.ready.remove(chosen)?;
        if let Some(since) = f.ready_since.take() {
            f.sched_delay += now.saturating_duration_since(since);
        }
        Some(f)
    }

    /// Pop the next admission-queue entry of workflow `idx`: deficit
    /// round robin picks *which tenant* to serve (weighted-fair across
    /// sub-queues), then the scheduling policy picks *which request*
    /// inside that tenant's sub-queue — fairness composes with SRTF.
    /// Queued requests are all stage 0, so `stage` ordering degrades to
    /// FIFO here and `deadline_slack` to EDF with a whole-request
    /// estimate.
    fn pop_queued(&self, s: &mut ShardState, idx: usize, now: Instant) -> Option<Queued> {
        let backlog: Vec<usize> = s.queues.iter().map(|q| q.len()).collect();
        let tenant = s.drr.next(&backlog)?;
        let est = self.stage_stats[idx].lock().unwrap().estimate(0);
        let chosen = pick(
            self.schedule,
            now,
            s.queues[tenant]
                .iter()
                .map(|j| Key { deadline: j.deadline, stage: 0, est_remaining: est }),
        )?;
        let job = s.queues[tenant].remove(chosen);
        if job.is_some() {
            self.depth_gauge[idx][tenant].fetch_sub(1, Ordering::Relaxed);
        }
        if s.queues[tenant].is_empty() {
            // the pop drained this tenant: forfeit its banked deficit
            // (classic DRR empty-queue rule — same as the cancel/expiry
            // paths), or a bursty tenant submitting between pops would
            // bank up to quantum−1 of entitlement earned while idle
            s.drr.on_empty(tenant);
        }
        job
    }

    /// Scheduler worker: multiplexes the in-flight table. Priority order
    /// per iteration: overdue deadline sweep (one worker claims it by
    /// CAS, then walks the shards one at a time), then woken
    /// continuations, then admission (bounded by `max_in_flight` via CAS
    /// reservation), else park on the condvar until an event or the next
    /// sweep is due.
    fn worker_loop(self: Arc<Self>, worker: usize) {
        let nkinds = self.kinds.len();
        let mut rot = worker; // stagger the shard scan start per worker
        loop {
            if self.stop.load(Ordering::Relaxed) {
                return;
            }
            let mut lapsed = Vec::new();
            if self.try_claim_sweep() {
                let now = self.clock.now();
                for idx in 0..nkinds {
                    let mut s = self.lock_shard(idx, HoldOp::Sweep);
                    self.collect_lapsed(&mut s, idx, now, &mut lapsed);
                    // re-poll continuations that had nothing to subscribe
                    // to (bounded backoff; see `ShardState::nudge`)
                    let nudge: Vec<u64> = s.nudge.drain(..).collect();
                    for rid in nudge {
                        if let Some(mut f) = s.parked.remove(&rid) {
                            if let Some(at) = f.parked_at.take() {
                                f.future_wait += now.saturating_duration_since(at);
                            }
                            f.ready_since = Some(now);
                            self.trace.record(f.request, TraceKind::Resumed, 0);
                            s.ready.push_back(f);
                        }
                    }
                }
            }
            let had_lapsed = !lapsed.is_empty();
            self.fail_lapsed(lapsed);
            // Event sequence read *before* the work scan: anything
            // arriving after this read bumps it, so the idle wait below
            // re-checks instead of sleeping through the event.
            let seq = *self.events.lock().unwrap();
            let now = self.clock.now();
            let mut task = None;
            for i in 0..nkinds {
                let idx = (rot + i) % nkinds;
                let mut s = self.lock_shard(idx, HoldOp::Poll);
                if let Some(f) = self.pop_ready(&mut s, idx, now) {
                    task = Some(Task::Poll(f));
                    break;
                }
            }
            if task.is_none() && self.try_reserve_total() {
                for i in 0..nkinds {
                    let idx = (rot + i) % nkinds;
                    let mut s = self.lock_shard(idx, HoldOp::Poll);
                    if let Some(job) = self.pop_queued(&mut s, idx, now) {
                        s.live.insert(job.request.0);
                        self.in_flight_gauge[idx].fetch_add(1, Ordering::Relaxed);
                        rot = rot.wrapping_add(1);
                        task = Some(Task::Admit(idx, job));
                        break;
                    }
                }
                if task.is_none() {
                    // reserved a slot but every admission queue was empty
                    self.release_total();
                }
            }
            match task {
                Some(Task::Poll(f)) => Self::run_poll(&self, f),
                Some(Task::Admit(idx, job)) => Self::admit(&self, idx, job),
                None => {
                    // idle, or at the in-flight cap: park until a
                    // submit/waker/capacity event or the next sweep is
                    // due — unless this iteration collected lapsed work,
                    // which was failed fast above and may have freed
                    // capacity worth re-scanning for at once.
                    if !had_lapsed {
                        let g = self.events.lock().unwrap();
                        if *g == seq {
                            let _ = self.cv.wait_timeout(g, SWEEP_PERIOD).unwrap();
                        }
                    }
                }
            }
        }
    }

    /// Collect every queued/parked request of one shard whose deadline
    /// has passed (fulfilment happens outside the lock, in
    /// [`Self::fail_lapsed`]). The sweep visits shards one at a time —
    /// an expiry freeing capacity in shard 0 may let a racing worker
    /// admit an already-expired queued job from a not-yet-swept shard,
    /// but `admit` checks the deadline first and counts it identically
    /// (`expired_in_queue`), so the outcome is race-invariant.
    fn collect_lapsed(&self, s: &mut ShardState, idx: usize, now: Instant, out: &mut Vec<Lapsed>) {
        for tenant in 0..s.queues.len() {
            let q = &mut s.queues[tenant];
            if q.iter().all(|j| j.deadline > now) {
                continue;
            }
            let mut kept = VecDeque::with_capacity(q.len());
            for job in q.drain(..) {
                if job.deadline <= now {
                    self.depth_gauge[idx][job.tenant].fetch_sub(1, Ordering::Relaxed);
                    out.push(Lapsed {
                        idx,
                        tenant: job.tenant,
                        submitted: job.submitted,
                        timeout: job.timeout,
                        cell: job.cell,
                        request: job.request,
                        retain_trace: job.retain_trace,
                        started: false,
                        stage_entered: Vec::new(),
                    });
                } else {
                    kept.push_back(job);
                }
            }
            let emptied = kept.is_empty();
            *q = kept;
            if emptied {
                // expiry emptied this tenant's sub-queue: it must not
                // bank its granted-but-unused DRR deficit
                s.drr.on_empty(tenant);
            }
        }
        // Ready entries expire too: a non-FIFO policy (`stage`) may defer
        // an expired entry's pop indefinitely, and an expired request must
        // not squat on an in-flight slot until the queue happens to drain.
        let mut i = 0;
        while i < s.ready.len() {
            if s.ready[i].deadline <= now {
                let f = s.ready.remove(i).expect("index in bounds");
                s.live.remove(&f.request.0);
                s.woken.remove(&f.request.0);
                s.cancelled.remove(&f.request.0);
                self.drop_in_flight(idx);
                out.push(Lapsed {
                    idx,
                    tenant: f.tenant,
                    submitted: f.submitted,
                    timeout: f.timeout,
                    cell: f.cell,
                    request: f.request,
                    retain_trace: f.retain_trace,
                    started: true,
                    stage_entered: f.stage_entered,
                });
            } else {
                i += 1;
            }
        }
        let overdue: Vec<u64> =
            s.parked.iter().filter(|(_, f)| f.deadline <= now).map(|(k, _)| *k).collect();
        for rid in overdue {
            let f = s.parked.remove(&rid).expect("collected above");
            s.live.remove(&rid);
            s.woken.remove(&rid);
            s.cancelled.remove(&rid);
            self.drop_in_flight(idx);
            out.push(Lapsed {
                idx,
                tenant: f.tenant,
                submitted: f.submitted,
                timeout: f.timeout,
                cell: f.cell,
                request: f.request,
                retain_trace: f.retain_trace,
                started: true,
                stage_entered: f.stage_entered,
            });
        }
    }

    /// Censored [`StageStats`] fold for a request that died (expired,
    /// cancelled, or failed) after finishing at least one stage: every
    /// stage the request *exited* contributes `died − entered` — a
    /// conservative lower bound on remaining-from-that-stage (the true
    /// remaining would be `completion − entered` ≥ that). The stage it
    /// died inside is skipped: that sample carries no progress signal.
    /// Without this, a fully overloaded front door (100% expiry) feeds
    /// the estimator nothing, and the `deadline_slack` policy and the
    /// JIT router fly blind exactly when they matter most.
    fn fold_censored_stages(&self, idx: usize, stage_entered: &[(u32, Instant)], died: Instant) {
        if stage_entered.len() < 2 {
            return;
        }
        let mut stats = self.stage_stats[idx].lock().unwrap();
        for (stage, entered) in &stage_entered[..stage_entered.len() - 1] {
            stats.observe(*stage, died.saturating_duration_since(*entered));
        }
    }

    /// Fail expired work fast: queued expiries count as `expired_in_queue`
    /// (the driver never ran), parked expiries as execution failures. A
    /// started request's outstanding futures are bulk-failed exactly like
    /// a cancel's — expiry is the same abandonment, and dead calls must
    /// not keep occupying engine queue slots (or holding wakers open). A
    /// cancel that won the race first keeps its outcome — `fulfil`
    /// arbitrates, the counters follow the winner.
    fn fail_lapsed(&self, lapsed: Vec<Lapsed>) {
        for l in lapsed {
            if l.started {
                self.fold_censored_stages(l.idx, &l.stage_entered, self.clock.now());
                self.d.table().fail_request(l.request, "request deadline expired");
            }
            let waited = self.since(l.submitted);
            if l.cell.fulfil(Err(Error::Deadline(l.timeout)), waited) {
                if !l.started {
                    self.expired_in_queue[l.idx][l.tenant].fetch_add(1, Ordering::Relaxed);
                } else {
                    self.failed[l.idx][l.tenant].fetch_add(1, Ordering::Relaxed);
                }
                self.trace.record(l.request, TraceKind::Expired, 0);
                self.journal.append(&journal::terminal(l.request.0, "expired", Value::Null));
                if !l.retain_trace {
                    self.trace.forget(l.request);
                }
            }
            self.maybe_publish(l.idx);
        }
    }

    /// [`Ticket::cancel`] target: remove the request from whichever table
    /// holds it and fulfil the ticket with `Error::Cancelled`. Returns
    /// true if the cancellation was delivered (the request was still
    /// live). Exactly-one-terminal-outcome holds because every terminal
    /// path owns its entry exclusively: a request is in at most one of
    /// {queue, ready, parked, being-polled}, and removal happens under
    /// its workflow's shard lock (the ticket carries `idx`, so the cancel
    /// keys straight into the owning shard).
    fn cancel(&self, idx: usize, request: RequestId) -> bool {
        let rid = request.0;
        enum Found {
            Queued(Queued),
            Started(InFlight),
            /// Mid-poll mark; the payload is whether *this* call set it
            /// (a second cancel in the same window must report false).
            Polling(bool),
            Gone,
        }
        let found = {
            let mut s = self.lock_shard(idx, HoldOp::Complete);
            let queued_at = s.queues.iter().enumerate().find_map(|(t, q)| {
                q.iter().position(|j| j.request.0 == rid).map(|pos| (t, pos))
            });
            if let Some((tenant, pos)) = queued_at {
                let job = s.queues[tenant].remove(pos).expect("position just found");
                self.depth_gauge[idx][tenant].fetch_sub(1, Ordering::Relaxed);
                if s.queues[tenant].is_empty() {
                    // cancel drained this tenant's sub-queue: forfeit its
                    // banked DRR deficit (same rule as the expiry sweep)
                    s.drr.on_empty(tenant);
                }
                Found::Queued(job)
            } else if let Some(f) = s.parked.remove(&rid) {
                s.live.remove(&rid);
                s.woken.remove(&rid);
                self.drop_in_flight(idx);
                Found::Started(f)
            } else if let Some(pos) = s.ready.iter().position(|f| f.request.0 == rid) {
                let f = s.ready.remove(pos).expect("position just found");
                s.live.remove(&rid);
                s.woken.remove(&rid);
                self.drop_in_flight(idx);
                Found::Started(f)
            } else if s.live.contains(&rid) {
                // Being polled right now — the only moment a live request
                // is in no table. Leave a mark the poller consumes when
                // the poll finishes (a Done poll means completion won).
                Found::Polling(s.cancelled.insert(rid))
            } else {
                Found::Gone
            }
        };
        match found {
            Found::Queued(job) => {
                if job.cell.fulfil(Err(Error::Cancelled), self.since(job.submitted)) {
                    self.cancelled[idx][job.tenant].fetch_add(1, Ordering::Relaxed);
                    self.trace.record(job.request, TraceKind::Cancelled, 0);
                    self.journal.append(&journal::terminal(
                        job.request.0,
                        "cancelled",
                        Value::Null,
                    ));
                    if !job.retain_trace {
                        self.trace.forget(job.request);
                    }
                }
                self.maybe_publish(idx);
                true
            }
            Found::Started(f) => {
                self.finish_cancelled(f);
                true
            }
            Found::Polling(delivered) => delivered,
            Found::Gone => false,
        }
    }

    /// Terminal path for a cancelled started request (entry already
    /// removed from the tables and gauges): bulk-fail its outstanding
    /// futures so nothing downstream waits on withdrawn work, fulfil the
    /// ticket, free the in-flight slot.
    fn finish_cancelled(&self, f: InFlight) {
        self.fold_censored_stages(f.idx, &f.stage_entered, self.clock.now());
        self.d.table().fail_request(f.request, "request cancelled");
        if f.cell.fulfil(Err(Error::Cancelled), self.since(f.submitted)) {
            self.cancelled[f.idx][f.tenant].fetch_add(1, Ordering::Relaxed);
            self.trace.record(f.request, TraceKind::Cancelled, 0);
            self.journal.append(&journal::terminal(f.request.0, "cancelled", Value::Null));
            if !f.retain_trace {
                self.trace.forget(f.request);
            }
        }
        self.maybe_publish(f.idx);
        self.notify(false); // in-flight capacity freed
    }

    /// Start one admitted request: build its resumable driver (unless the
    /// submitter handed one in) and poll it. (`this` instead of a
    /// receiver: wakers need the `Arc` to clone.)
    fn admit(this: &Arc<Self>, idx: usize, mut job: Queued) {
        let now = this.clock.now();
        if now >= job.deadline {
            // expired while queued: fail fast, never build the driver
            {
                let mut s = this.lock_shard(idx, HoldOp::Complete);
                s.live.remove(&job.request.0);
                s.cancelled.remove(&job.request.0);
                this.drop_in_flight(idx);
            }
            if job.cell.fulfil(Err(Error::Deadline(job.timeout)), this.since(job.submitted)) {
                this.expired_in_queue[idx][job.tenant].fetch_add(1, Ordering::Relaxed);
                this.trace.record(job.request, TraceKind::Expired, 0);
                this.journal.append(&journal::terminal(job.request.0, "expired", Value::Null));
                if !job.retain_trace {
                    this.trace.forget(job.request);
                }
            }
            this.maybe_publish(idx);
            this.notify(false); // in-flight capacity freed
            return;
        }
        this.trace.record(job.request, TraceKind::Scheduled, 0);
        this.journal.append(&journal::started(job.request.0));
        let mut env = Env::with_request(&this.d, job.session, job.request);
        // Per-request routing hint, shared with the env's stubs: the
        // decision point in `run_poll` stamps it before every poll, and
        // its consumptions land on this (workflow, tenant)'s counter row.
        let hint = this.route.as_ref().map(|rs| {
            RouteHint::with_counters(rs.clone(), Some(this.routed[idx][job.tenant].clone()))
        });
        env.ctx.route = hint.clone();
        let driver = match job.driver.take() {
            Some(driver) => driver,
            None => driver_for(this.kinds[idx], &job.input),
        };
        Self::run_poll(
            this,
            InFlight {
                idx,
                request: job.request,
                tenant: job.tenant,
                driver,
                env,
                hint,
                submitted: job.submitted,
                deadline: job.deadline,
                timeout: job.timeout,
                cell: job.cell,
                retain_trace: job.retain_trace,
                subscribed: HashSet::new(),
                stage: 0,
                stage_entered: vec![(0, now)],
                queue_wait: now.saturating_duration_since(job.submitted),
                sched_delay: Duration::ZERO,
                poll_time: Duration::ZERO,
                future_wait: Duration::ZERO,
                ready_since: None,
                parked_at: None,
            },
        );
    }

    /// Poll one continuation: advance it as far as readiness allows, then
    /// either finish it or park it under waker subscriptions.
    fn run_poll(this: &Arc<Self>, mut f: InFlight) {
        let poll_started = this.clock.now();
        if poll_started >= f.deadline {
            let timeout = f.timeout;
            // same abandonment as the sweep path: dead calls must not
            // keep engine slots or wakers alive
            this.d.table().fail_request(f.request, "request deadline expired");
            this.finish(f, Err(Error::Deadline(timeout)));
            return;
        }
        // JIT routing decision point (DESIGN.md §13): refresh the hint
        // against the request's *current* deadline slack right before the
        // driver runs, so every call it issues from this poll dispatches
        // under the freshest decision. Slack is signed: remaining
        // deadline budget minus the stage's remaining-work estimate.
        if let (Some(rs), Some(hint)) = (&this.route, &f.hint) {
            let est = this.stage_stats[f.idx].lock().unwrap().estimate(f.stage);
            let budget = f.deadline.saturating_duration_since(poll_started).as_secs_f64();
            let slack = budget - est.map_or(0.0, |e| e.as_secs_f64());
            let over = this.tenant_adm[f.idx][f.tenant].over_budget(poll_started);
            let prev = hint.get();
            let dec = rs.decide(Some(slack), est.map(|e| e.as_secs_f64()), over);
            hint.set(dec);
            // Traced on decision *change* only, so a steady request's
            // timeline carries one Routed mark, not one per poll.
            if prev.map(|p| p.variant) != Some(dec.variant) {
                this.trace.record(f.request, TraceKind::Routed, dec.variant as u64);
            }
        }
        this.trace.record(f.request, TraceKind::Polling, f.stage as u64);
        let step = f.driver.poll(&f.env);
        let after = this.clock.now();
        f.poll_time += after.saturating_duration_since(poll_started);
        match step {
            Step::Done(result) => this.finish(f, result),
            Step::Pending { waiting_on } => {
                let rid = f.request.0;
                let shard = f.idx;
                let first_wait = waiting_on.first().map_or(0, |id| id.0);
                // Track stage progress for the scheduling policies (the
                // driver advanced as far as readiness allowed before
                // suspending, so `stage()` is current).
                let stage = f.driver.stage();
                if stage > f.stage {
                    f.stage = stage;
                    f.stage_entered.push((stage, this.clock.now()));
                }
                // Journal snapshot, serialized *outside* the shard lock
                // (driver state can be arbitrarily large) but appended
                // inside it, only on the branch that actually parks — a
                // mid-poll wakeup re-runs instead and needs no record.
                let snapshot = if this.journal.enabled() {
                    let waiting: Vec<u64> = waiting_on.iter().map(|id| id.0).collect();
                    Some(journal::parked(rid, f.stage, f.driver.serialize_state(), &waiting))
                } else {
                    None
                };
                // Resolve the not-yet-subscribed cells *before* parking:
                // once parked, another worker may take the continuation at
                // any moment. Already-subscribed futures keep their
                // original waker (one per future per request).
                let mut cells: Vec<(u64, Arc<FutureCell>)> = Vec::new();
                let mut can_wake = false;
                for id in &waiting_on {
                    if f.subscribed.contains(&id.0) {
                        can_wake = true;
                        continue;
                    }
                    if let Some(cell) = this.d.table().get(*id) {
                        f.subscribed.insert(id.0);
                        cells.push((id.0, cell));
                        can_wake = true;
                    }
                }
                let cancelled = {
                    let mut s = this.lock_shard(shard, HoldOp::Poll);
                    if s.cancelled.remove(&rid) {
                        // a cancel landed mid-poll: this request parks
                        // nowhere — it is terminal now
                        s.live.remove(&rid);
                        s.woken.remove(&rid);
                        this.drop_in_flight(shard);
                        Some(f)
                    } else if s.woken.remove(&rid) {
                        // a waker fired mid-poll: run again rather than
                        // risk a lost wakeup. Traced as a zero-length
                        // park + resume so the event-derived and
                        // accumulator decompositions agree: the requeue
                        // wait is sched-delay in both.
                        f.ready_since = Some(after);
                        this.trace.record(f.request, TraceKind::Parked, first_wait);
                        this.trace.record(f.request, TraceKind::Resumed, 0);
                        s.ready.push_back(f);
                        None
                    } else {
                        f.parked_at = Some(after);
                        this.trace.record(f.request, TraceKind::Parked, first_wait);
                        if let Some(rec) = &snapshot {
                            this.journal.append(rec);
                        }
                        s.parked.insert(rid, f);
                        if !can_wake {
                            // nothing is subscribable (a shouldn't-happen:
                            // stubs register every future) — let the next
                            // sweep re-poll it instead of hot-spinning
                            s.nudge.push(rid);
                        }
                        None
                    }
                };
                if let Some(f) = cancelled {
                    this.finish_cancelled(f);
                    return;
                }
                // Subscribe after parking: a future that resolved in the
                // gap fires the waker inline, which finds the parked entry
                // and moves it to ready — no wakeup is lost. The waker
                // holds a Weak ref: a strong one would cycle (table →
                // cell → waker → scheduler → deployment → table) and leak
                // the whole deployment through any never-terminal cell.
                // It captures the shard index alongside the request id,
                // so the wake keys straight into the owning lock domain.
                for (fid, cell) in cells {
                    let inner = Arc::downgrade(this);
                    cell.subscribe(Box::new(move || {
                        if let Some(inner) = inner.upgrade() {
                            // Journal the resolution *before* the wake: a
                            // crash between the two replays conservatively
                            // (the future is re-issued), never optimistically.
                            inner.journal.append(&journal::resolved(rid, fid));
                            inner.wake(shard, rid);
                        }
                    }));
                }
            }
        }
    }

    /// Waker target: move a parked continuation to the ready queue. Fired
    /// by future resolution from component-controller threads. Touches
    /// only the owning shard's lock (`idx` was captured when the waker
    /// subscribed).
    fn wake(&self, idx: usize, rid: u64) {
        let now = self.clock.now();
        let mut s = self.lock_shard(idx, HoldOp::Wake);
        if let Some(mut f) = s.parked.remove(&rid) {
            if let Some(at) = f.parked_at.take() {
                f.future_wait += now.saturating_duration_since(at);
            }
            f.ready_since = Some(now);
            self.trace.record(f.request, TraceKind::Resumed, 0);
            s.ready.push_back(f);
            drop(s);
            self.notify(false);
        } else if s.live.contains(&rid) {
            // being polled right now: record the wakeup for the poller
            s.woken.insert(rid);
        }
        // else: the request already finished — stale waker, nothing to do
    }

    /// Account and fulfil one finished request.
    fn finish(&self, f: InFlight, result: Result<Value>) {
        {
            let mut s = self.lock_shard(f.idx, HoldOp::Complete);
            s.live.remove(&f.request.0);
            s.woken.remove(&f.request.0);
            s.cancelled.remove(&f.request.0); // completion won the race
            self.drop_in_flight(f.idx);
        }
        // Engine-service total must be read *before* the completion hook
        // evicts the per-request future index.
        let service_us = self.d.table().request_service_us(f.request);
        // Request-completion hook: evict the per-request future index —
        // the request is terminal, nothing will `fail_request` it, and
        // the index must not grow unboundedly (futures::table).
        self.d.table().on_request_complete(f.request);
        let now = self.clock.now();
        let ok = result.is_ok();
        if ok {
            // Feed the per-stage remaining-time stats with complete
            // observations.
            let mut stats = self.stage_stats[f.idx].lock().unwrap();
            for (stage, entered) in &f.stage_entered {
                stats.observe(*stage, now.saturating_duration_since(*entered));
            }
        } else {
            // Died mid-flight (deadline expiry on the poll path, driver
            // error): exited stages still carry real timing — see
            // `fold_censored_stages`. The stage it died in is excluded,
            // so a truncated "remaining" never teaches the slack policy
            // that doomed requests finish fast.
            self.fold_censored_stages(f.idx, &f.stage_entered, now);
        }
        let latency = now.saturating_duration_since(f.submitted);
        // Built before `fulfil` consumes the result; appended only if this
        // path won the terminal race (the journal, like the counters,
        // records exactly one terminal outcome per request).
        let term = if self.journal.enabled() {
            let (outcome, detail) = match &result {
                Ok(v) => ("done", v.clone()),
                Err(e) => ("failed", Value::Str(e.to_string())),
            };
            Some(journal::terminal(f.request.0, outcome, detail))
        } else {
            None
        };
        if f.cell.fulfil(result, latency) {
            let ctr = if ok { &self.completed } else { &self.failed };
            ctr[f.idx][f.tenant].fetch_add(1, Ordering::Relaxed);
            if ok {
                // Fold the decomposition into the per-(workflow, tenant)
                // histograms (successes only, matching `StageStats` —
                // truncated failures would skew the quantiles low).
                self.breakdown[f.idx][f.tenant].record_ns(
                    f.queue_wait.as_nanos() as u64,
                    f.sched_delay.as_nanos() as u64,
                    f.poll_time.as_nanos() as u64,
                    f.future_wait.as_nanos() as u64,
                    service_us * 1_000,
                );
            }
            let kind = if ok { TraceKind::Done } else { TraceKind::Failed };
            self.trace.record(f.request, kind, latency.as_nanos() as u64);
            if let Some(rec) = &term {
                self.journal.append(rec);
            }
            // Terminal in-proc exit: the histogram fold above already
            // consumed the decomposition, so the timeline is dead weight
            // in the bounded ring unless the submitter opted in
            // ([`SubmitRequest::retain_trace`] — the HTTP plane, which
            // evicts on registry consumption instead).
            if !f.retain_trace {
                self.trace.forget(f.request);
            }
        }
        self.maybe_publish(f.idx);
        self.notify(false); // in-flight capacity freed: admit more
    }
}

/// What one journal replay did ([`Ingress::recover`]), surfaced through
/// [`Ingress::recovery`] and the recovery bench's report.
#[derive(Clone, Debug, Default)]
pub struct RecoveryStats {
    /// In-flight requests re-admitted (tickets re-issued).
    pub recovered: usize,
    /// Requests the journal proved terminal — skipped, not re-run.
    pub skipped_complete: u64,
    /// In-flight requests that could not be replayed (workflow not served
    /// by this ingress, or unknown tenant on a configured table).
    pub lost: usize,
    /// Corrupt / torn / orphaned journal lines tolerated during load.
    pub corrupt: u64,
}

/// [`Ingress::recover`]'s result: fresh tickets for the re-admitted
/// requests (original [`RequestId`]s — callers polling by id keep
/// working) plus the replay accounting.
pub struct RecoveryOutcome {
    pub tickets: Vec<Ticket>,
    pub stats: RecoveryStats,
}

/// See module docs.
pub struct Ingress {
    inner: Arc<IngressInner>,
    joins: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// Stats of the startup replay [`Self::start`] ran (None = no journal
    /// configured, or an explicit `start_with*` that skipped recovery).
    recovery: Mutex<Option<RecoveryStats>>,
}

impl Ingress {
    /// Start a front door for `kinds` using the deployment's configured
    /// admission settings (`DeploymentConfig.ingress`). If
    /// `ingress.journal.path` is set, the existing journal is replayed
    /// first — completed requests are skipped, in-flight ones re-admitted
    /// ([`Self::recover`], stats via [`Self::recovery`]) — and every
    /// lifecycle transition of the new run is journaled there.
    pub fn start(d: &Deployment, kinds: &[WorkflowKind]) -> Ingress {
        let s = &d.cfg().ingress;
        let policy = AdmissionPolicy::from_settings(s);
        if s.journal.path.is_empty() {
            return Self::start_with(d, kinds, policy, s.workers);
        }
        let path = std::path::PathBuf::from(&s.journal.path);
        // A journal that fails to load or open degrades the node to
        // non-durable serving, loudly — it never blocks startup (report,
        // don't mask: the operator sees it, requests still flow).
        let plan = journal::load(&path).unwrap_or_else(|e| {
            eprintln!("journal: load {} failed ({e}); starting with an empty plan", path.display());
            RecoveryPlan::default()
        });
        let fsync = journal::FsyncPolicy::parse(&s.journal.fsync)
            .unwrap_or(journal::FsyncPolicy::Batch);
        let sink = JournalSink::open(&path, fsync).unwrap_or_else(|e| {
            eprintln!("journal: open {} failed ({e}); journaling disabled", path.display());
            JournalSink::disabled()
        });
        let mut opts = SchedulerOpts::new(s.workers, s.max_in_flight);
        opts.journal = sink;
        let ing = Self::start_with_opts(d, kinds, policy, opts);
        // Replayed tickets are dropped: recovered requests complete
        // headless (their terminal outcome lands in the journal and the
        // counters); wire callers re-poll by request id after reconnect.
        let outcome = ing.recover(&plan);
        *ing.recovery.lock().unwrap() = Some(outcome.stats);
        ing
    }

    /// Start with an explicit admission policy and scheduler thread count
    /// (everything else comes from the deployment config).
    pub fn start_with(
        d: &Deployment,
        kinds: &[WorkflowKind],
        policy: AdmissionPolicy,
        workers: usize,
    ) -> Ingress {
        let max_in_flight = d.cfg().ingress.max_in_flight;
        Self::start_with_opts(d, kinds, policy, SchedulerOpts::new(workers, max_in_flight))
    }

    /// Start with explicit scheduler sizing, scheduling policy and clock.
    pub fn start_with_opts(
        d: &Deployment,
        kinds: &[WorkflowKind],
        policy: AdmissionPolicy,
        opts: SchedulerOpts,
    ) -> Ingress {
        assert!(!kinds.is_empty(), "ingress needs at least one workflow");
        let workers = opts.workers.max(1);
        let schedule =
            opts.schedule.unwrap_or_else(|| SchedulePolicy::from_settings(&d.cfg().ingress));
        let clock = opts.clock.clone();
        // Tenant table: the deployment's `ingress.tenants`, or the
        // implicit single `default` tenant — under which every structure
        // below degenerates to the pre-tenancy single queue exactly.
        let cfg_tenants = &d.cfg().ingress.tenants;
        let tenants_configured = !cfg_tenants.is_empty();
        let tenants: Vec<TenantSpec> = if tenants_configured {
            cfg_tenants
                .iter()
                .map(|t| TenantSpec { name: t.name.clone(), weight: t.weight })
                .collect()
        } else {
            vec![TenantSpec { name: "default".into(), weight: 1.0 }]
        };
        let weights: Vec<f64> = tenants.iter().map(|t| t.weight).collect();
        let tenant_policies: Vec<AdmissionPolicy> = if tenants_configured {
            cfg_tenants.iter().map(AdmissionPolicy::for_tenant).collect()
        } else {
            vec![AdmissionPolicy::Unbounded]
        };
        let per_tenant_u64 = |_: &WorkflowKind| -> Vec<AtomicU64> {
            weights.iter().map(|_| AtomicU64::new(0)).collect()
        };
        // The flight recorder: explicit sink if the caller injected one
        // (tests share a recorder across assertions), else a fresh one
        // sized by `ingress.trace.capacity` on the scheduler's clock.
        // Installed into the deployment's shared slot so component
        // controllers overlay engine dispatch/complete events onto the
        // same timelines.
        let trace = opts
            .trace
            .clone()
            .unwrap_or_else(|| TraceSink::recording(d.cfg().ingress.trace.capacity, clock.clone()));
        d.trace_slot().install(trace.clone());
        // The JIT router: built from the validated config and installed
        // into the deployment's shared slot (late-bound, like the trace
        // sink) so the global and component controllers operate on the
        // same instance. `None` — no variants declared, or route "fixed"
        // — keeps dispatch byte-for-byte the pre-routing path.
        let route = RouteState::from_config(d.cfg());
        if let Some(rs) = &route {
            d.route_slot().install(rs.clone());
        }
        let nvariants = route.as_ref().map_or(0, |r| r.variants().len());
        let epoch = clock.now();
        let inner = Arc::new(IngressInner {
            d: d.clone(),
            kinds: kinds.to_vec(),
            tenants,
            tenants_configured,
            shards: kinds
                .iter()
                .map(|_| {
                    Mutex::new(ShardState {
                        queues: weights.iter().map(|_| VecDeque::new()).collect(),
                        drr: Drr::new(&weights),
                        ready: VecDeque::new(),
                        parked: HashMap::new(),
                        woken: HashSet::new(),
                        cancelled: HashSet::new(),
                        nudge: Vec::new(),
                        live: HashSet::new(),
                    })
                })
                .collect(),
            events: Mutex::new(0),
            cv: Condvar::new(),
            admission: kinds.iter().map(|_| AdmissionController::new(policy.clone())).collect(),
            tenant_adm: kinds
                .iter()
                .map(|_| {
                    tenant_policies
                        .iter()
                        .map(|p| AdmissionController::new(p.clone()))
                        .collect()
                })
                .collect(),
            completed: kinds.iter().map(per_tenant_u64).collect(),
            failed: kinds.iter().map(per_tenant_u64).collect(),
            expired_in_queue: kinds.iter().map(per_tenant_u64).collect(),
            cancelled: kinds.iter().map(per_tenant_u64).collect(),
            stage_stats: kinds.iter().map(|_| Mutex::new(StageStats::new())).collect(),
            route,
            routed: kinds
                .iter()
                .map(|_| {
                    weights
                        .iter()
                        .map(|_| {
                            Arc::new((0..nvariants).map(|_| AtomicU64::new(0)).collect::<Vec<_>>())
                        })
                        .collect()
                })
                .collect(),
            breakdown: kinds
                .iter()
                .map(|_| weights.iter().map(|_| StageHistograms::new()).collect())
                .collect(),
            trace,
            schedule,
            clock,
            workers,
            max_in_flight: opts.max_in_flight.max(1),
            depth_gauge: kinds
                .iter()
                .map(|_| weights.iter().map(|_| AtomicUsize::new(0)).collect())
                .collect(),
            in_flight_gauge: kinds.iter().map(|_| AtomicUsize::new(0)).collect(),
            total_in_flight: AtomicUsize::new(0),
            epoch,
            next_sweep: AtomicU64::new(SWEEP_PERIOD.as_nanos() as u64),
            last_publish: kinds.iter().map(|_| AtomicU64::new(0)).collect(),
            hold: opts.hold.clone(),
            journal: opts.journal.clone(),
            stop: AtomicBool::new(false),
        });
        let joins = (0..workers)
            .map(|w| {
                let inner = inner.clone();
                std::thread::Builder::new()
                    .name(format!("nalar-ingress-{w}"))
                    .spawn(move || inner.worker_loop(w))
                    .expect("spawn ingress worker")
            })
            .collect();
        for idx in 0..kinds.len() {
            inner.publish(idx); // make the queue visible to policies at once
        }
        Ingress { inner, joins: Mutex::new(joins), recovery: Mutex::new(None) }
    }

    /// Accept or shed one request — the single front-door entry point
    /// (the HTTP serving plane, the loadgen and every suite funnel
    /// through here; see [`SubmitRequest`] for what a submission
    /// carries). Non-blocking: on acceptance the request is queued and
    /// the caller gets a [`Ticket`]; on shed the caller gets a retryable
    /// [`Error::Shed`] immediately. The deadline is counted from
    /// admission.
    pub fn submit(&self, req: SubmitRequest) -> Result<Ticket> {
        let SubmitRequest { kind, input, driver, session, tenant, timeout, retain_trace } = req;
        let inner = &self.inner;
        let idx = inner
            .kind_index(kind)
            .ok_or_else(|| Error::Config(format!("ingress does not serve `{}`", kind.name())))?;
        let tenant = inner.tenant_index(tenant.as_deref())?;
        let verdict = {
            let mut s = inner.lock_shard(idx, HoldOp::Submit);
            // Checked under the shard lock: `stop` drains each shard
            // under its own lock after setting the flag, so a submit
            // either lands before that shard's drain (and is failed by
            // it) or observes the flag here — no ticket is ever left
            // unfulfilled.
            if inner.stop.load(Ordering::Relaxed) {
                return Err(Error::Shed(kind.name().into(), "ingress stopped".into(), None));
            }
            // Composed admission, decided against the scheduler's clock
            // (a token bucket must refill on the same time axis deadlines
            // run on, or virtual-clock tests get wall-clock-dependent
            // verdicts): the shared policy sees the workflow's total
            // queued depth, then the tenant's own bucket — and the final
            // verdict is counted exactly once, on the tenant's
            // controller (the aggregate counters are per-tenant sums).
            // The depth gauge only moves under this shard's lock, so the
            // bounded-cap check is as exact as it was under one big lock.
            let now = inner.clock.now();
            let decision = inner.admission[idx].decide_at(inner.depth_of(idx), now).and_then(
                |()| {
                    // Prefix the reason with the tenant, keeping the
                    // structured retry rate intact — `Error::retry_after`
                    // reads the rate, never the reason string.
                    inner.tenant_adm[idx][tenant].decide_at(0, now).map_err(|s| {
                        admission::Shed {
                            reason: format!(
                                "tenant `{}`: {}",
                                inner.tenants[tenant].name, s.reason
                            ),
                            ..s
                        }
                    })
                },
            );
            inner.tenant_adm[idx][tenant].record(decision.is_ok());
            match decision {
                Ok(()) => {
                    let session = session.unwrap_or_else(|| inner.d.new_session());
                    let request = inner.d.new_request_id();
                    let cell = TicketCell::new();
                    // First two timeline events, recorded inside the shard
                    // lock so they cannot interleave after `Scheduled` from
                    // a racing worker that pops the job immediately.
                    inner.trace.record(request, TraceKind::Admitted, 0);
                    inner.trace.record(request, TraceKind::Queued, tenant as u64);
                    // Admission record under the shard lock: file order =
                    // admission order, and no later record of this request
                    // (started/parked/terminal) can precede it.
                    inner.journal.append(&journal::admitted(
                        request.0,
                        session.0,
                        &inner.tenants[tenant].name,
                        kind.name(),
                        &input,
                        timeout.as_millis() as u64,
                    ));
                    s.queues[tenant].push_back(Queued {
                        session,
                        request,
                        tenant,
                        input,
                        driver,
                        submitted: now,
                        deadline: now + timeout,
                        timeout,
                        cell: cell.clone(),
                        retain_trace,
                    });
                    inner.depth_gauge[idx][tenant].fetch_add(1, Ordering::Relaxed);
                    Ok(Ticket {
                        request,
                        session,
                        tenant: TenantId(tenant as u64),
                        cell,
                        idx,
                        inner: Arc::downgrade(&self.inner),
                    })
                }
                Err(shed) => Err(Error::Shed(kind.name().into(), shed.reason, shed.retry_rate)),
            }
        };
        if verdict.is_ok() {
            inner.notify(false);
        }
        inner.maybe_publish(idx);
        verdict
    }

    /// Current depth of a workflow's admission queue (requests not yet
    /// started; started work is [`Self::in_flight`]). Reads the atomic
    /// gauge — no shard lock, so the HTTP `/metrics` and `/healthz`
    /// handlers can never stall behind a busy scheduler.
    pub fn depth(&self, kind: WorkflowKind) -> usize {
        match self.inner.kind_index(kind) {
            Some(idx) => self.inner.depth_of(idx),
            None => 0,
        }
    }

    /// Started-but-unfinished requests for a workflow (the multiplexing
    /// gauge: in-flight ÷ workers is how many requests each thread is
    /// carrying). Lock-free, like [`Self::depth`].
    pub fn in_flight(&self, kind: WorkflowKind) -> usize {
        match self.inner.kind_index(kind) {
            Some(idx) => self.inner.in_flight_gauge[idx].load(Ordering::Relaxed),
            None => 0,
        }
    }

    /// Telemetry snapshot for one workflow queue (same struct the global
    /// controller aggregates).
    pub fn metrics(&self, kind: WorkflowKind) -> Option<IngressMetrics> {
        Some(self.inner.snapshot(self.inner.kind_index(kind)?))
    }

    /// The flight recorder this scheduler writes span timelines into
    /// (disabled sink when `ingress.trace.capacity` is 0). The HTTP trace
    /// endpoint and the `nalar trace` waterfall read timelines from here.
    pub fn trace(&self) -> &TraceSink {
        &self.inner.trace
    }

    /// The durable request journal this scheduler appends to (disabled
    /// unless [`SchedulerOpts::journal`] or `ingress.journal.path`
    /// installed one).
    pub fn journal(&self) -> &JournalSink {
        &self.inner.journal
    }

    /// Stats of the startup journal replay, when [`Self::start`] ran one.
    pub fn recovery(&self) -> Option<RecoveryStats> {
        self.recovery.lock().unwrap().clone()
    }

    /// Replay a crashed node's [`RecoveryPlan`] into this (fresh) ingress
    /// with the standard driver factory ([`restore_driver`]).
    pub fn recover(&self, plan: &RecoveryPlan) -> RecoveryOutcome {
        self.recover_with(plan, |kind, input, state| restore_driver(kind, input, state))
    }

    /// Replay with a caller-supplied driver factory `(kind, input,
    /// snapshot) -> Driver` — how the deterministic replay suites inject
    /// [`crate::testkit::ScriptedEngine`] drivers. Replay invariants
    /// (DESIGN.md §12):
    ///
    /// * **Original ids.** Re-admitted requests keep their journaled
    ///   `RequestId`/`SessionId`, and the id generators are advanced past
    ///   every journaled id first — new work never collides with replayed
    ///   work.
    /// * **Exactly one terminal outcome, across incarnations.** Requests
    ///   with a journaled terminal record are skipped entirely. In-flight
    ///   ones are re-admitted with a *fresh* ticket cell; their pre-crash
    ///   futures are failed (`superseded by recovery`) so a late resolve
    ///   hits the resolve-after-fail drop path instead of waking a ghost.
    /// * **Futures re-issue, never resurrect.** A `parked` snapshot
    ///   records the driver's resume point; its re-built driver re-issues
    ///   that stage's calls afresh. Journaled `resolved` records are
    ///   advisory (crash-window forensics), not replayed state.
    /// * **Deadlines restart at recovery.** The journaled budget is
    ///   re-counted from the replay instant — the dead node's wall time is
    ///   not this node's, and instantly expiring every survivor would make
    ///   recovery a mass failure.
    /// * Admission policy is bypassed (each request was already admitted
    ///   once); the accept is still counted so tenant counters stay
    ///   consistent with queue contents.
    pub fn recover_with(
        &self,
        plan: &RecoveryPlan,
        mut factory: impl FnMut(WorkflowKind, &Value, &Value) -> Box<dyn Driver>,
    ) -> RecoveryOutcome {
        let inner = &self.inner;
        inner.d.advance_ids(plan.max_session, plan.max_request, plan.max_future);
        let mut stats = RecoveryStats {
            skipped_complete: plan.completed,
            corrupt: plan.corrupt,
            ..RecoveryStats::default()
        };
        let mut tickets = Vec::new();
        let mut touched: HashSet<usize> = HashSet::new();
        let now = inner.clock.now();
        for entry in &plan.inflight {
            let Some(idx) = inner.kinds.iter().position(|k| k.name() == entry.workflow) else {
                stats.lost += 1;
                continue;
            };
            let tenant = if inner.tenants_configured {
                match inner.tenants.iter().position(|t| t.name == entry.tenant) {
                    Some(t) => t,
                    None => {
                        stats.lost += 1;
                        continue;
                    }
                }
            } else {
                0
            };
            let request = RequestId(entry.request);
            inner.d.table().fail_request(request, "superseded by recovery");
            let driver = factory(inner.kinds[idx], &entry.input, &entry.state);
            let timeout = Duration::from_millis(entry.timeout_ms);
            let cell = TicketCell::new();
            {
                let mut s = inner.lock_shard(idx, HoldOp::Submit);
                inner.trace.record(request, TraceKind::Admitted, 0);
                inner.trace.record(request, TraceKind::Queued, tenant as u64);
                // Fresh admission record: `load` is latest-admit-wins, so
                // a second crash replays from this incarnation's state.
                inner.journal.append(&journal::admitted(
                    entry.request,
                    entry.session,
                    &inner.tenants[tenant].name,
                    &entry.workflow,
                    &entry.input,
                    entry.timeout_ms,
                ));
                s.queues[tenant].push_back(Queued {
                    session: SessionId(entry.session),
                    request,
                    tenant,
                    input: entry.input.clone(),
                    driver: Some(driver),
                    submitted: now,
                    deadline: now + timeout,
                    timeout,
                    cell: cell.clone(),
                    retain_trace: false,
                });
                inner.depth_gauge[idx][tenant].fetch_add(1, Ordering::Relaxed);
            }
            inner.tenant_adm[idx][tenant].record(true);
            touched.insert(idx);
            stats.recovered += 1;
            tickets.push(Ticket {
                request,
                session: SessionId(entry.session),
                tenant: TenantId(tenant as u64),
                cell,
                idx,
                inner: Arc::downgrade(&self.inner),
            });
        }
        inner.notify(true);
        for idx in touched {
            inner.publish(idx);
        }
        RecoveryOutcome { tickets, stats }
    }

    /// Stop the scheduler: workers finish the poll they are executing;
    /// everything queued or parked fails fast (reported, not masked — §5).
    /// Idempotent; also runs on drop.
    pub fn stop(&self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        self.inner.notify(true);
        for j in self.joins.lock().unwrap().drain(..) {
            let _ = j.join();
        }
        // Drain shard by shard, each under its own lock (pairs with the
        // stop check in `submit` — the flag is already set, so a submit
        // racing a drain either lands before it and is failed by it, or
        // observes the flag and sheds), fulfil outside the locks. Workers
        // are already joined, so nothing is mid-poll: `live` is exactly
        // ready + parked.
        let (queued, inflight): (Vec<(usize, Queued)>, Vec<InFlight>) = {
            let mut queued = Vec::new();
            let mut inflight: Vec<InFlight> = Vec::new();
            for idx in 0..self.inner.kinds.len() {
                let mut s = self.inner.lock_shard(idx, HoldOp::Complete);
                for (tenant, dq) in s.queues.iter_mut().enumerate() {
                    for j in dq.drain(..) {
                        self.inner.depth_gauge[idx][tenant].fetch_sub(1, Ordering::Relaxed);
                        queued.push((idx, j));
                    }
                }
                let drained = s.ready.len() + s.parked.len();
                inflight.extend(s.ready.drain(..));
                inflight.extend(s.parked.drain().map(|(_, f)| f));
                for _ in 0..drained {
                    self.inner.drop_in_flight(idx);
                }
                s.live.clear();
                s.woken.clear();
                s.cancelled.clear();
                s.nudge.clear();
            }
            (queued, inflight)
        };
        for (idx, job) in queued {
            let kind = self.inner.kinds[idx].name().to_string();
            let waited = self.inner.since(job.submitted);
            if job.cell.fulfil(Err(Error::Shed(kind, "ingress stopped".into(), None)), waited) {
                self.inner.failed[idx][job.tenant].fetch_add(1, Ordering::Relaxed);
                self.inner.trace.record(job.request, TraceKind::Shed, 0);
                self.inner.journal.append(&journal::terminal(job.request.0, "shed", Value::Null));
            }
        }
        for f in inflight {
            // Same abandonment as cancel/expiry: a started request's
            // outstanding futures must not keep engine slots or wakers
            // alive through shutdown (this also evicts its entry from
            // the per-request future index).
            self.inner.d.table().fail_request(f.request, "ingress stopped");
            let kind = self.inner.kinds[f.idx].name().to_string();
            let waited = self.inner.since(f.submitted);
            if f.cell.fulfil(Err(Error::Shed(kind, "ingress stopped".into(), None)), waited) {
                self.inner.failed[f.idx][f.tenant].fetch_add(1, Ordering::Relaxed);
                self.inner.trace.record(f.request, TraceKind::Shed, 0);
                self.inner.journal.append(&journal::terminal(f.request.0, "shed", Value::Null));
            }
        }
        // A graceful stop journals a terminal for everything it drained,
        // so a restart over the same journal recovers nothing — recovery
        // is for crashes ([`Self::halt`]), not shutdowns.
        self.inner.journal.sync();
        for idx in 0..self.inner.kinds.len() {
            self.inner.publish(idx);
        }
    }

    /// Simulated crash (`nalar bench recovery`, the replay suites): stop
    /// the workers and *abandon* every queued and in-flight request — no
    /// ticket is fulfilled, no terminal outcome is journaled. Exactly what
    /// power loss leaves behind: a journal whose last record for each live
    /// request is `admitted`/`started`/`parked`, which is what
    /// [`Self::recover`] replays on the next start. The journal is synced
    /// so the crash point is durable; the in-memory tables are cleared so
    /// the subsequent `Drop`-driven [`Self::stop`] finds nothing to shed.
    pub fn halt(&self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        self.inner.notify(true);
        for j in self.joins.lock().unwrap().drain(..) {
            let _ = j.join();
        }
        for idx in 0..self.inner.kinds.len() {
            let mut s = self.inner.lock_shard(idx, HoldOp::Complete);
            for (tenant, dq) in s.queues.iter_mut().enumerate() {
                for _ in dq.drain(..) {
                    self.inner.depth_gauge[idx][tenant].fetch_sub(1, Ordering::Relaxed);
                }
            }
            let drained = s.ready.len() + s.parked.len();
            s.ready.clear();
            s.parked.clear();
            for _ in 0..drained {
                self.inner.drop_in_flight(idx);
            }
            s.live.clear();
            s.woken.clear();
            s.cancelled.clear();
            s.nudge.clear();
        }
        self.inner.journal.sync();
    }
}

impl Drop for Ingress {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;
    use crate::testkit::ScriptedEngine;

    fn fast_router() -> Deployment {
        let mut cfg = WorkflowKind::Router.config();
        cfg.time_scale = 0.0005;
        cfg.control.global_period_ms = 10;
        Deployment::launch(cfg).unwrap()
    }

    fn router_input() -> Value {
        json!({"prompt": "hello", "class": "chat"})
    }

    /// The common builder chain, shortened for the suites below.
    fn req(kind: WorkflowKind, input: Value, timeout: Duration) -> SubmitRequest {
        SubmitRequest::workflow(kind).input(input).deadline(timeout)
    }

    #[test]
    fn submits_complete_through_the_scheduler() {
        let d = fast_router();
        let ing = Ingress::start_with(&d, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, 4);
        let timeout = Duration::from_secs(20);
        let tickets: Vec<Ticket> = (0..8)
            .map(|_| ing.submit(req(WorkflowKind::Router, router_input(), timeout)).unwrap())
            .collect();
        for t in &tickets {
            let out = t.wait(timeout).unwrap();
            assert!(!out.is_null());
            assert!(t.latency().unwrap() > Duration::ZERO);
        }
        let m = ing.metrics(WorkflowKind::Router).unwrap();
        assert_eq!(m.accepted, 8);
        assert_eq!(m.completed, 8);
        assert_eq!(m.shed, 0);
        assert_eq!(m.cancelled, 0);
        assert_eq!(m.in_flight, 0, "everything drained");
        assert_eq!(m.workers, 4);
        assert_eq!(m.schedule, "fifo", "configs default to FIFO");
        // distinct request ids were stamped at admission
        let mut ids: Vec<u64> = tickets.iter().map(|t| t.request.0).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 8);
        ing.stop();
        d.shutdown();
    }

    #[test]
    fn bounded_queue_sheds_fast_and_never_exceeds_cap() {
        let mut cfg = WorkflowKind::Router.config();
        cfg.time_scale = 0.002; // slow enough that a tiny scheduler falls behind
        let d = Deployment::launch(cfg).unwrap();
        let cap = 4;
        // One thread, two in-flight slots: the queue must back up and shed.
        let ing = Ingress::start_with_opts(
            &d,
            &[WorkflowKind::Router],
            AdmissionPolicy::Bounded { cap },
            SchedulerOpts::new(1, 2),
        );
        let timeout = Duration::from_secs(30);
        let mut tickets = Vec::new();
        let mut sheds = 0;
        for _ in 0..40 {
            match ing.submit(req(WorkflowKind::Router, router_input(), timeout)) {
                Ok(t) => tickets.push(t),
                Err(e) => {
                    // fails fast with a retryable shed error
                    assert!(matches!(e, Error::Shed(..)), "{e}");
                    assert!(e.retryable());
                    sheds += 1;
                }
            }
            assert!(ing.depth(WorkflowKind::Router) <= cap, "bounded queue exceeded its cap");
        }
        assert!(sheds > 0, "a 2-slot scheduler must fall behind a 40-request burst");
        let m = ing.metrics(WorkflowKind::Router).unwrap();
        assert_eq!(m.shed, sheds);
        assert_eq!(m.cap, cap);
        for t in &tickets {
            let _ = t.wait(timeout); // accepted work still drains
        }
        ing.stop();
        d.shutdown();
    }

    #[test]
    fn expired_deadline_fails_fast_without_running() {
        let d = fast_router();
        let ing = Ingress::start_with(&d, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, 1);
        let t = ing
            .submit(req(WorkflowKind::Router, router_input(), Duration::ZERO))
            .unwrap();
        let err = t.wait(Duration::from_secs(5)).unwrap_err();
        assert!(matches!(err, Error::Deadline(..)), "{err}");
        assert!(err.retryable());
        // counted as an in-queue expiry, NOT an execution failure
        let m = ing.metrics(WorkflowKind::Router).unwrap();
        assert_eq!(m.expired_in_queue, 1);
        assert_eq!(m.failed, 0);
        ing.stop();
        d.shutdown();
    }

    #[test]
    fn telemetry_lands_in_global_controller_view() {
        let d = fast_router();
        let ing = Ingress::start_with(
            &d,
            &[WorkflowKind::Router],
            AdmissionPolicy::Bounded { cap: 64 },
            2,
        );
        let timeout = Duration::from_secs(20);
        let tickets: Vec<Ticket> = (0..4)
            .map(|_| ing.submit(req(WorkflowKind::Router, router_input(), timeout)).unwrap())
            .collect();
        for t in &tickets {
            t.wait(timeout).unwrap();
        }
        // publishes are throttled on the hot path; stop() flushes the
        // final state, which the global controller then aggregates.
        ing.stop();
        let view = d.global().collect();
        let ingress = view
            .ingress
            .iter()
            .find(|i| i.workflow == "router")
            .expect("ingress telemetry missing from cluster view");
        assert_eq!(ingress.accepted, 4);
        assert_eq!(ingress.completed, 4);
        assert_eq!(ingress.policy, "bounded");
        assert_eq!(ingress.schedule, "fifo", "scheduling policy must reach policies");
        assert_eq!(ingress.cap, 64);
        assert_eq!(ingress.workers, 2, "thread gauge must reach policies");
        assert_eq!(ingress.expired_in_queue, 0);
        assert_eq!(ingress.cancelled, 0);
        d.shutdown();
    }

    #[test]
    fn stop_fails_queued_work_and_rejects_new_submits() {
        let mut cfg = WorkflowKind::Router.config();
        cfg.time_scale = 0.002;
        let d = Deployment::launch(cfg).unwrap();
        let ing = Ingress::start_with(&d, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, 1);
        let timeout = Duration::from_secs(30);
        let tickets: Vec<Ticket> = (0..10)
            .map(|_| ing.submit(req(WorkflowKind::Router, router_input(), timeout)).unwrap())
            .collect();
        ing.stop();
        let failures = tickets
            .iter()
            .filter(|t| t.wait(Duration::from_secs(1)).is_err())
            .count();
        assert!(failures >= 1, "queued work must fail fast at shutdown");
        assert!(ing.submit(req(WorkflowKind::Router, router_input(), timeout)).is_err());
        d.shutdown();
    }

    #[test]
    fn unserved_workflow_is_a_config_error() {
        let d = fast_router();
        let ing = Ingress::start_with(&d, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, 1);
        let err = ing
            .submit(req(WorkflowKind::Swe, json!({"task": "t"}), Duration::from_secs(1)))
            .unwrap_err();
        assert!(matches!(err, Error::Config(..)), "{err}");
        ing.stop();
        d.shutdown();
    }

    #[test]
    fn custom_drivers_ride_the_same_front_door() {
        let d = fast_router();
        let ing = Ingress::start_with(&d, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, 2);
        let eng = ScriptedEngine::new();
        let timeout = Duration::from_secs(10);
        let t = ing
            .submit(
                SubmitRequest::workflow(WorkflowKind::Router)
                    .driver(eng.driver("custom", 1))
                    .deadline(timeout),
            )
            .unwrap();
        assert!(eng.wait_created(1, Duration::from_secs(5)), "scripted call must be issued");
        eng.cell(0).resolve(json!("done"), 0);
        let out = t.wait(Duration::from_secs(5)).unwrap();
        assert_eq!(out.get("scripted").as_str(), Some("custom"));
        let m = ing.metrics(WorkflowKind::Router).unwrap();
        assert_eq!(m.completed, 1);
        ing.stop();
        d.shutdown();
    }

    #[test]
    fn cancel_of_a_parked_request_is_terminal_and_fails_its_futures() {
        let d = fast_router();
        let ing = Ingress::start_with(&d, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, 2);
        let eng = ScriptedEngine::new();
        let timeout = Duration::from_secs(30);
        let t = ing
            .submit(
                SubmitRequest::workflow(WorkflowKind::Router)
                    .driver(eng.driver("doomed", 1))
                    .deadline(timeout),
            )
            .unwrap();
        assert!(eng.wait_created(1, Duration::from_secs(5)));
        assert!(t.cancel(), "a parked request must be cancellable");
        let err = t.wait(Duration::from_secs(5)).unwrap_err();
        assert!(matches!(err, Error::Cancelled), "{err}");
        assert!(!err.retryable());
        assert!(!t.cancel(), "second cancel finds nothing");
        // the outstanding scripted future was bulk-failed
        assert!(eng.cell(0).try_value().unwrap().is_err());
        let m = ing.metrics(WorkflowKind::Router).unwrap();
        assert_eq!(m.cancelled, 1);
        assert_eq!(m.completed, 0);
        assert_eq!(m.failed, 0, "cancellation is not an execution failure");
        assert_eq!(m.in_flight, 0, "no table leak");
        assert_eq!(m.depth, 0);
        ing.stop();
        d.shutdown();
    }

    #[test]
    fn implicit_single_tenant_backs_every_plain_submit() {
        // No `ingress.tenants` block: the table is the implicit
        // `default`, every name collapses onto it, and the aggregate
        // counters equal the single tenant's.
        let mut cfg = WorkflowKind::Router.config();
        cfg.time_scale = 0.0005;
        cfg.control.global_period_ms = 10;
        cfg.ingress.tenants.clear();
        let d = Deployment::launch(cfg).unwrap();
        let ing = Ingress::start_with(&d, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, 2);
        let timeout = Duration::from_secs(20);
        let t1 = ing.submit(req(WorkflowKind::Router, router_input(), timeout)).unwrap();
        let t2 = ing
            .submit(req(WorkflowKind::Router, router_input(), timeout).tenant("x"))
            .unwrap();
        assert_eq!(t1.tenant, TenantId(0));
        assert_eq!(t2.tenant, TenantId(0), "unnamed table: any name collapses onto it");
        t1.wait(timeout).unwrap();
        t2.wait(timeout).unwrap();
        let m = ing.metrics(WorkflowKind::Router).unwrap();
        assert_eq!(m.tenants.len(), 1);
        assert_eq!(m.tenants[0].tenant, "default");
        assert_eq!(m.tenants[0].weight, 1.0);
        assert_eq!(m.tenants[0].accepted, 2);
        assert_eq!(m.tenants[0].completed, 2);
        assert_eq!(m.accepted, 2, "aggregate = per-tenant sum");
        ing.stop();
        d.shutdown();
    }

    #[test]
    fn tenant_token_bucket_sheds_only_the_offending_tenant() {
        let mut cfg = WorkflowKind::Router.config();
        cfg.time_scale = 0.0005;
        cfg.control.global_period_ms = 10;
        cfg.ingress.tenants = vec![
            crate::config::TenantSettings {
                name: "hog".into(),
                weight: 1.0,
                // negligible refill: only the 2-token burst ever admits
                token_rate: 1e-9,
                token_burst: 2.0,
            },
            crate::config::TenantSettings {
                name: "meek".into(),
                weight: 1.0,
                token_rate: 0.0,
                token_burst: 32.0,
            },
        ];
        let d = Deployment::launch(cfg).unwrap();
        let ing = Ingress::start_with(&d, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, 2);
        let timeout = Duration::from_secs(30);
        let mut hog_tickets = Vec::new();
        let mut hog_sheds = 0;
        for _ in 0..5 {
            match ing.submit(req(WorkflowKind::Router, router_input(), timeout).tenant("hog")) {
                Ok(t) => {
                    assert_eq!(t.tenant, TenantId(0), "tenant stamped at admission");
                    hog_tickets.push(t);
                }
                Err(e) => {
                    assert!(matches!(e, Error::Shed(..)), "{e}");
                    assert!(e.to_string().contains("tenant `hog`"), "shed names the tenant: {e}");
                    hog_sheds += 1;
                }
            }
        }
        assert_eq!(hog_tickets.len(), 2, "only the burst admits");
        assert_eq!(hog_sheds, 3);
        // the meek tenant is untouched by the hog's exhausted bucket
        let meek: Vec<Ticket> = (0..3)
            .map(|_| {
                ing.submit(req(WorkflowKind::Router, router_input(), timeout).tenant("meek"))
                    .unwrap()
            })
            .collect();
        assert_eq!(meek[0].tenant, TenantId(1));
        for t in hog_tickets.iter().chain(meek.iter()) {
            t.wait(timeout).unwrap();
        }
        let m = ing.metrics(WorkflowKind::Router).unwrap();
        let hog = m.tenants.iter().find(|t| t.tenant == "hog").unwrap();
        let meek_m = m.tenants.iter().find(|t| t.tenant == "meek").unwrap();
        assert_eq!((hog.accepted, hog.shed, hog.completed), (2, 3, 2));
        assert_eq!((meek_m.accepted, meek_m.shed, meek_m.completed), (3, 0, 3));
        assert_eq!(m.accepted, 5, "aggregate accepted = tenant sum");
        assert_eq!(m.shed, 3, "aggregate shed = tenant sum");
        // typos must not silently share someone else's bucket
        let err = ing
            .submit(req(WorkflowKind::Router, router_input(), timeout).tenant("hgo"))
            .unwrap_err();
        assert!(matches!(err, Error::Config(..)), "{err}");
        ing.stop();
        d.shutdown();
    }

    #[test]
    fn submit_request_builder_defaults_and_overrides() {
        let r = SubmitRequest::workflow(WorkflowKind::Router);
        assert!(matches!(r.input, Value::Null));
        assert!(r.driver.is_none());
        assert!(r.session.is_none());
        assert!(r.tenant.is_none());
        assert_eq!(r.timeout, SubmitRequest::DEFAULT_DEADLINE);
        let r = r.input(router_input()).tenant("hog").deadline(Duration::from_secs(5));
        assert_eq!(r.tenant.as_deref(), Some("hog"));
        assert_eq!(r.timeout, Duration::from_secs(5));
        assert!(r.input.get("prompt").as_str().is_some());
    }

    /// The builder is the only submit surface (the pre-`SubmitRequest`
    /// shims are gone): session continuation, custom drivers and the
    /// default chain all flow through `submit` and feed one counter set.
    #[test]
    fn builder_is_the_single_submit_surface() {
        let d = fast_router();
        let ing = Ingress::start_with(&d, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, 4);
        let timeout = Duration::from_secs(20);

        // Session continuation: a builder submit with `.session(..)` keeps
        // the caller's session; one without mints a fresh one.
        let sess = d.new_session();
        let cont = ing
            .submit(req(WorkflowKind::Router, router_input(), timeout).session(sess))
            .unwrap();
        let fresh = ing.submit(req(WorkflowKind::Router, router_input(), timeout)).unwrap();
        assert_eq!(cont.session, sess);
        assert_ne!(fresh.session, sess);
        assert_eq!(cont.tenant, fresh.tenant, "both land on the implicit tenant");
        cont.wait(timeout).unwrap();
        fresh.wait(timeout).unwrap();

        // Custom drivers ride the same path: `.driver(..)` replaces the
        // workflow's built-in driver without a separate entry point.
        let eng = ScriptedEngine::new();
        let t_a = ing
            .submit(
                SubmitRequest::workflow(WorkflowKind::Router)
                    .driver(eng.driver("shim", 1))
                    .deadline(timeout),
            )
            .unwrap();
        let t_b = ing
            .submit(
                SubmitRequest::workflow(WorkflowKind::Router)
                    .driver(eng.driver("shim", 1))
                    .deadline(timeout),
            )
            .unwrap();
        assert!(eng.wait_created(2, Duration::from_secs(5)), "both drivers must run");
        for i in 0..2 {
            eng.cell(i).resolve(json!("done"), 0);
        }
        for t in [t_a, t_b] {
            let out = t.wait(Duration::from_secs(5)).unwrap();
            assert_eq!(out.get("scripted").as_str(), Some("shim"));
        }
        let m = ing.metrics(WorkflowKind::Router).unwrap();
        assert_eq!(m.completed, 4, "every surface feeds the same counters");
        assert_eq!(m.in_flight, 0, "no table leak via either surface");
        ing.stop();
        d.shutdown();
    }

    /// Tentpole acceptance: on a virtual clock the span timeline is exact
    /// — every lifecycle event lands at a known instant, and the
    /// event-derived stage decomposition sums to the ticket's reported
    /// latency with zero slack (the clock only moves when the test says
    /// so, so "within clock granularity" collapses to equality).
    #[test]
    fn trace_timeline_is_exact_on_a_virtual_clock() {
        use crate::trace::stage_durations;
        let (clock, v) = Clock::manual();
        let d = fast_router();
        let trace = TraceSink::recording(4096, clock.clone());
        let mut opts = SchedulerOpts::new(1, 1);
        opts.clock = clock.clone();
        opts.trace = Some(trace.clone());
        let ing =
            Ingress::start_with_opts(&d, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, opts);
        let eng = ScriptedEngine::new();
        let timeout = Duration::from_secs(60);
        // One worker, in-flight bound 1: r1 runs first and r2 sits in the
        // admission queue until r1 finishes — so r2's queue wait is
        // exactly the virtual time r1 spends parked on its future.
        let t1 = ing
            .submit(
                SubmitRequest::workflow(WorkflowKind::Router)
                    .driver(eng.driver("r1", 1))
                    .deadline(timeout),
            )
            .unwrap();
        let t2 = ing
            .submit(
                SubmitRequest::workflow(WorkflowKind::Router)
                    .driver(eng.driver("r2", 1))
                    .deadline(timeout),
            )
            .unwrap();
        // Timeline-driven sync (wall-bounded): the Parked event is
        // recorded under the scheduler lock, so once it is visible the
        // request is parked and virtual time can advance safely.
        let wait_parked = |t: &Ticket| {
            for _ in 0..4000 {
                if trace.timeline(t.request).iter().any(|e| e.kind == TraceKind::Parked) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            panic!("request never parked");
        };
        wait_parked(&t1); // r1 issued its scripted call at t=0 and parked
        v.advance(Duration::from_secs(2)); // r1 future-wait
        eng.cell(0).resolve(json!("a"), 1_500_000); // 1.5 s engine service
        t1.wait(Duration::from_secs(10)).unwrap();
        wait_parked(&t2); // freed slot admitted r2; it parked at t=2 s
        v.advance(Duration::from_secs(3)); // r2 future-wait
        eng.cell(1).resolve(json!("b"), 250_000);
        t2.wait(Duration::from_secs(10)).unwrap();

        let sec = |n: u64| Duration::from_secs(n).as_nanos() as u64;
        let shape = vec![
            TraceKind::Admitted,
            TraceKind::Queued,
            TraceKind::Scheduled,
            TraceKind::Polling,
            TraceKind::Parked,
            TraceKind::Resumed,
            TraceKind::Polling,
            TraceKind::Done,
        ];
        let tl1 = trace.timeline(t1.request);
        assert_eq!(tl1.iter().map(|e| e.kind).collect::<Vec<_>>(), shape);
        assert_eq!(
            tl1.iter().map(|e| e.clock_ns).collect::<Vec<_>>(),
            vec![0, 0, 0, 0, 0, sec(2), sec(2), sec(2)],
        );
        let s1 = stage_durations(&tl1);
        assert_eq!(s1.future_wait_ns, sec(2));
        assert_eq!(s1.queue_wait_ns + s1.sched_delay_ns + s1.poll_ns, 0);
        assert_eq!(s1.sum_ns(), t1.latency().unwrap().as_nanos() as u64);

        let tl2 = trace.timeline(t2.request);
        assert_eq!(tl2.iter().map(|e| e.kind).collect::<Vec<_>>(), shape, "same lifecycle");
        assert_eq!(
            tl2.iter().map(|e| e.clock_ns).collect::<Vec<_>>(),
            vec![0, 0, sec(2), sec(2), sec(2), sec(5), sec(5), sec(5)],
        );
        let s2 = stage_durations(&tl2);
        assert_eq!(s2.queue_wait_ns, sec(2), "r2 queued behind r1");
        assert_eq!(s2.future_wait_ns, sec(3));
        assert_eq!(s2.sum_ns(), sec(5));
        assert_eq!(s2.sum_ns(), t2.latency().unwrap().as_nanos() as u64);
        assert_eq!(trace.dropped(), 0);

        // The same completions fed the per-stage histograms: each
        // quantile lands in the log-spaced bucket holding the exact value
        // (upper bound within a ×1.3 bucket width of it).
        let m = ing.metrics(WorkflowKind::Router).unwrap();
        let b = &m.breakdown;
        assert_eq!(b.queue_wait.count, 2);
        assert!(b.queue_wait.p95 >= 2.0 && b.queue_wait.p95 <= 2.0 * 1.3, "{}", b.queue_wait.p95);
        assert!(
            b.future_wait.p95 >= 3.0 && b.future_wait.p95 <= 3.0 * 1.3,
            "{}",
            b.future_wait.p95
        );
        assert!(
            b.engine_service.p95 >= 1.5 && b.engine_service.p95 <= 1.5 * 1.3,
            "{}",
            b.engine_service.p95
        );
        assert!(b.poll_time.p99 <= 2e-6, "virtual poll time is zero: {}", b.poll_time.p99);
        assert_eq!(m.trace_dropped, 0);
        ing.stop();
        d.shutdown();
    }

    /// Tracing off (`capacity` 0 → disabled sink): requests still serve,
    /// timelines are just empty — the recorder is strictly an observer.
    #[test]
    fn disabled_trace_sink_serves_without_timelines() {
        let d = fast_router();
        let mut opts = SchedulerOpts::new(2, 8);
        opts.trace = Some(TraceSink::disabled());
        let ing =
            Ingress::start_with_opts(&d, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, opts);
        let timeout = Duration::from_secs(20);
        let t = ing.submit(req(WorkflowKind::Router, router_input(), timeout)).unwrap();
        t.wait(timeout).unwrap();
        assert!(ing.trace().timeline(t.request).is_empty());
        assert!(!ing.trace().enabled());
        let m = ing.metrics(WorkflowKind::Router).unwrap();
        assert_eq!(m.trace_dropped, 0);
        assert_eq!(m.breakdown.queue_wait.count, 1, "histograms fold regardless of tracing");
        ing.stop();
        d.shutdown();
    }

    /// ISSUE 8 acceptance: no scheduler-shard lock is acquired anywhere on
    /// the metrics read path. This thread *holds* a shard lock while
    /// another thread runs the full read path — snapshot (what
    /// `ing.metrics` and `GET /metrics` serve), publish (the coordinator
    /// collect path), and the depth / in-flight gauges — and must see it
    /// complete. If any of those ever re-acquires a shard lock, the
    /// reader blocks and the receive below times out.
    #[test]
    fn metrics_read_path_never_takes_a_shard_lock() {
        let d = fast_router();
        let ing = Ingress::start_with(&d, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, 2);
        let timeout = Duration::from_secs(20);
        let t = ing.submit(req(WorkflowKind::Router, router_input(), timeout)).unwrap();
        t.wait(timeout).unwrap();

        let guard = ing.inner.shards[0].lock().unwrap();
        let inner = ing.inner.clone();
        let (tx, rx) = std::sync::mpsc::channel();
        let reader = std::thread::spawn(move || {
            let m = inner.snapshot(0);
            inner.publish(0);
            let depth = inner.depth_of(0);
            let in_flight = inner.in_flight_gauge[0].load(Ordering::Relaxed);
            tx.send((m.completed, depth, in_flight)).unwrap();
        });
        let (completed, depth, in_flight) = rx
            .recv_timeout(Duration::from_secs(5))
            .expect("metrics read path blocked behind a held shard lock");
        assert_eq!(completed, 1);
        assert_eq!(depth, 0);
        assert_eq!(in_flight, 0, "the drained gauge is served from atomics");
        drop(guard);
        reader.join().unwrap();
        ing.stop();
        d.shutdown();
    }

    /// Satellite fix: an in-proc submit's timeline is evicted at its
    /// terminal outcome (after the histogram fold) — completed local
    /// requests must not squat in the bounded ring until eviction rolls
    /// over live entries — while `.retain_trace()` (the HTTP plane's
    /// mode, which evicts on registry consumption) keeps it.
    #[test]
    fn in_proc_terminal_exit_evicts_the_timeline_unless_retained() {
        let d = fast_router();
        let ing = Ingress::start_with(&d, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, 2);
        let timeout = Duration::from_secs(20);
        let t = ing.submit(req(WorkflowKind::Router, router_input(), timeout)).unwrap();
        t.wait(timeout).unwrap();
        // The ticket is fulfilled a hair before the forget runs; poll
        // (wall-bounded) rather than race it.
        let mut evicted = false;
        for _ in 0..4000 {
            if ing.trace().timeline(t.request).is_empty() {
                evicted = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(evicted, "a completed local request's timeline must be gone");
        assert!(ing.trace().enabled(), "eviction is not a disabled sink");

        let kept = ing
            .submit(req(WorkflowKind::Router, router_input(), timeout).retain_trace())
            .unwrap();
        kept.wait(timeout).unwrap();
        let tl = ing.trace().timeline(kept.request);
        assert!(
            tl.iter().any(|e| e.kind == TraceKind::Done),
            "retain_trace keeps the full timeline through the terminal event"
        );
        let m = ing.metrics(WorkflowKind::Router).unwrap();
        assert_eq!(m.trace_dropped, 0, "eviction is a forget, never a ring drop");
        assert_eq!(m.breakdown.queue_wait.count, 2, "histograms folded before eviction");
        ing.stop();
        d.shutdown();
    }

    /// Fresh path for a journal file under the OS temp dir (no toolchain
    /// for tempfile crates — pid + tag keeps parallel test runs apart).
    fn temp_journal(tag: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("nalar-journal-test-{}-{tag}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&p);
        p
    }

    /// Terminal-record lines of a journal file, verbatim.
    fn terminal_lines(path: &std::path::Path) -> Vec<String> {
        std::fs::read_to_string(path)
            .unwrap_or_default()
            .lines()
            .filter(|l| l.contains("\"t\":\"terminal\""))
            .map(|l| l.to_string())
            .collect()
    }

    /// Tentpole acceptance: a run that crashes mid-request and recovers
    /// journals a terminal record *byte-identical* to an uninterrupted
    /// reference run's — same request id (replay keeps originals), same
    /// outcome, same result value — with zero leaked scheduler slots or
    /// future-index entries after recovery.
    #[test]
    fn journal_replay_reproduces_identical_terminal_outcomes() {
        let timeout = Duration::from_secs(60);
        let submit_scripted = |ing: &Ingress, eng: &Arc<ScriptedEngine>| {
            ing.submit(
                SubmitRequest::workflow(WorkflowKind::Router)
                    .driver(eng.driver("r1", 1))
                    .deadline(timeout),
            )
            .unwrap()
        };
        let wait_parked = |ing: &Ingress, t: &Ticket| {
            for _ in 0..4000 {
                if ing.trace().timeline(t.request).iter().any(|e| e.kind == TraceKind::Parked) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            panic!("request never parked");
        };

        // Reference: the same submission, served without interruption.
        let ref_path = temp_journal("ref");
        {
            let d = fast_router();
            let mut opts = SchedulerOpts::new(1, 4);
            opts.journal = JournalSink::open(&ref_path, journal::FsyncPolicy::Always).unwrap();
            let ing = Ingress::start_with_opts(
                &d,
                &[WorkflowKind::Router],
                AdmissionPolicy::Unbounded,
                opts,
            );
            let eng = ScriptedEngine::new();
            let t = submit_scripted(&ing, &eng);
            assert!(eng.wait_created(1, Duration::from_secs(5)));
            eng.cell(0).resolve(json!("a"), 0);
            t.wait(Duration::from_secs(10)).unwrap();
            ing.stop();
            d.shutdown();
        }

        // Crash run: identical submission, node halted while parked.
        let crash_path = temp_journal("crash");
        {
            let d = fast_router();
            let mut opts = SchedulerOpts::new(1, 4);
            opts.journal = JournalSink::open(&crash_path, journal::FsyncPolicy::Always).unwrap();
            let ing = Ingress::start_with_opts(
                &d,
                &[WorkflowKind::Router],
                AdmissionPolicy::Unbounded,
                opts,
            );
            let eng = ScriptedEngine::new();
            let t = submit_scripted(&ing, &eng);
            assert!(eng.wait_created(1, Duration::from_secs(5)));
            wait_parked(&ing, &t);
            ing.halt(); // simulated power loss: no terminal journaled
            assert!(t.try_take().is_none(), "a crash fulfils nothing");
            d.shutdown();
        }

        // Recovery incarnation: fresh deployment (fresh id generators —
        // a new process), same journal.
        let plan = journal::load(&crash_path).unwrap();
        assert_eq!(plan.inflight.len(), 1, "the parked request is in-flight in the journal");
        assert_eq!(plan.completed, 0);
        let d2 = fast_router();
        let mut opts = SchedulerOpts::new(1, 4);
        opts.journal = JournalSink::open(&crash_path, journal::FsyncPolicy::Always).unwrap();
        let ing2 = Ingress::start_with_opts(
            &d2,
            &[WorkflowKind::Router],
            AdmissionPolicy::Unbounded,
            opts,
        );
        let eng2 = ScriptedEngine::new();
        let outcome = ing2.recover_with(&plan, |_, _, _| eng2.driver("r1", 1));
        assert_eq!(outcome.stats.recovered, 1);
        assert_eq!(outcome.stats.lost, 0);
        assert_eq!(outcome.stats.corrupt, 0);
        let t2 = &outcome.tickets[0];
        assert_eq!(t2.request.0, plan.inflight[0].request, "replay keeps the original id");
        assert!(
            d2.new_request_id().0 > plan.max_request,
            "fresh ids are advanced past every replayed one"
        );
        assert!(eng2.wait_created(1, Duration::from_secs(5)), "replay re-issues the future");
        eng2.cell(0).resolve(json!("a"), 0);
        let out = t2.wait(Duration::from_secs(10)).unwrap();
        assert_eq!(out.get("scripted").as_str(), Some("r1"));
        // Bookkeeping lands an instant after fulfilment: settle, bounded.
        for _ in 0..4000 {
            let m = ing2.metrics(WorkflowKind::Router).unwrap();
            if m.completed == 1
                && (m.depth, m.in_flight) == (0, 0)
                && d2.table().request_index_len() == 0
            {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        let m = ing2.metrics(WorkflowKind::Router).unwrap();
        assert_eq!(m.completed, 1);
        assert_eq!((m.depth, m.in_flight), (0, 0), "no leaked scheduler slots");
        assert_eq!(d2.table().request_index_len(), 0, "no leaked future-index entries");
        ing2.stop();
        d2.shutdown();

        // Byte-identical terminal outcomes across the crash.
        let reference = terminal_lines(&ref_path);
        assert_eq!(reference.len(), 1, "the reference run journals exactly one terminal record");
        assert_eq!(
            reference,
            terminal_lines(&crash_path),
            "recovery must reproduce the uninterrupted run's terminal record, byte for byte"
        );
        let _ = std::fs::remove_file(&ref_path);
        let _ = std::fs::remove_file(&crash_path);
    }

    /// Satellite 1 (ISSUE 10): the slack estimator must keep learning
    /// under overload. Every request below finishes stage 0 and then dies
    /// on its deadline — a 100%-expiry trace. The old success-only fold
    /// fed `StageStats` nothing here, starving the `deadline_slack`
    /// estimate exactly when overload made it matter; censored folds from
    /// exited stages make it converge.
    #[test]
    fn stage_stats_converge_under_a_total_expiry_trace() {
        let (clock, v) = Clock::manual();
        let d = fast_router();
        let mut opts = SchedulerOpts::new(1, 4);
        opts.clock = clock.clone();
        let ing =
            Ingress::start_with_opts(&d, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, opts);
        let eng = ScriptedEngine::new();
        assert!(
            ing.inner.stage_stats[0].lock().unwrap().estimate(0).is_none(),
            "fresh estimator"
        );
        for i in 0..3 {
            let t = ing
                .submit(
                    SubmitRequest::workflow(WorkflowKind::Router)
                        .driver(eng.driver("doomed", 2))
                        .deadline(Duration::from_secs(5)),
                )
                .unwrap();
            assert!(eng.wait_created(2 * i + 1, Duration::from_secs(5)));
            // finish stage 0 after one virtual second...
            v.advance(Duration::from_secs(1));
            eng.cell(2 * i).resolve(json!("s0"), 0);
            assert!(eng.wait_created(2 * i + 2, Duration::from_secs(5)), "stage 1 call issued");
            // ...then die parked in stage 1, well past the deadline.
            v.advance(Duration::from_secs(10));
            let err = t.wait(Duration::from_secs(5)).unwrap_err();
            assert!(matches!(err, Error::Deadline(..)), "{err}");
        }
        let m = ing.metrics(WorkflowKind::Router).unwrap();
        assert_eq!(m.completed, 0, "the trace is 100% expiry");
        assert_eq!(m.failed, 3, "every request died after starting");
        {
            let stats = ing.inner.stage_stats[0].lock().unwrap();
            let est = stats
                .estimate(0)
                .expect("censored folds must feed the estimator with zero successes");
            assert!(est >= Duration::from_secs(1), "lower-bound sample, got {est:?}");
            assert!(
                stats.estimate(1).is_none(),
                "the stage a request died in is excluded (no progress signal)"
            );
        }
        ing.stop();
        d.shutdown();
    }

    /// Satellite 4 (ISSUE 10): deterministic router A/B on the virtual
    /// clock. The same seeded mixed-slack trace runs once pinned to the
    /// large variant and once under `route = "jit"`: jit routes
    /// negative-slack requests to the fast variant and strictly reduces
    /// deadline misses at identical load, the per-variant counters sum
    /// to the total number of dispatches, and the tables drain to zero.
    #[test]
    fn jit_routing_beats_fixed_large_on_a_mixed_slack_trace() {
        use crate::config::ModelVariant;

        // Alternating tight (1 s) and loose (20 s) deadlines.
        const TRACE: [u64; 8] = [1, 20, 1, 20, 1, 20, 1, 20];
        const BASE_SERVICE_S: f64 = 2.0;

        let run = |route: &str| -> (u64, Vec<(String, u64)>, usize) {
            let (clock, v) = Clock::manual();
            let mut cfg = WorkflowKind::Router.config();
            cfg.time_scale = 0.0005;
            cfg.control.global_period_ms = 10;
            cfg.engine.variants = vec![
                ModelVariant { name: "fast".into(), latency_mult: 0.35, quality: 0.82 },
                ModelVariant { name: "base".into(), latency_mult: 1.0, quality: 0.92 },
                ModelVariant { name: "large".into(), latency_mult: 2.2, quality: 0.99 },
            ];
            cfg.ingress.route = route.into();
            let d = Deployment::launch(cfg).unwrap();
            let mut opts = SchedulerOpts::new(2, 8);
            opts.clock = clock.clone();
            let ing = Ingress::start_with_opts(
                &d,
                &[WorkflowKind::Router],
                AdmissionPolicy::Unbounded,
                opts,
            );
            let eng = ScriptedEngine::new();
            let mult_of = |call: usize| -> f64 {
                match eng.variant_of(call).as_deref() {
                    Some("fast") => 0.35,
                    Some("large") => 2.2,
                    _ => 1.0,
                }
            };
            // Warm the slack estimator with two completed requests so the
            // decision point has a remaining-work estimate.
            let mut call = 0;
            for _ in 0..2 {
                let t = ing
                    .submit(
                        SubmitRequest::workflow(WorkflowKind::Router)
                            .driver(eng.driver("warm", 1))
                            .deadline(Duration::from_secs(60)),
                    )
                    .unwrap();
                assert!(eng.wait_created(call + 1, Duration::from_secs(5)));
                v.advance(Duration::from_secs_f64(BASE_SERVICE_S * mult_of(call)));
                eng.cell(call).resolve(json!("w"), 0);
                t.wait(Duration::from_secs(5)).unwrap();
                call += 1;
            }
            // The measured trace: each request issues one call, and the
            // test plays engine latency as the base service time scaled
            // by the call's *routed* variant, on the virtual clock — so
            // the routing decision is what decides each deadline race.
            let mut misses = 0u64;
            for deadline_s in TRACE {
                let t = ing
                    .submit(
                        SubmitRequest::workflow(WorkflowKind::Router)
                            .driver(eng.driver("req", 1))
                            .deadline(Duration::from_secs(deadline_s)),
                    )
                    .unwrap();
                assert!(eng.wait_created(call + 1, Duration::from_secs(5)));
                let service = BASE_SERVICE_S * mult_of(call);
                v.advance(Duration::from_secs_f64(service));
                if service < deadline_s as f64 {
                    eng.cell(call).resolve(json!("out"), 0);
                }
                if t.wait(Duration::from_secs(5)).is_err() {
                    misses += 1;
                }
                call += 1;
            }
            let m = ing.metrics(WorkflowKind::Router).unwrap();
            assert_eq!(m.route, route, "snapshot reports the configured route");
            assert_eq!(
                m.tenants[0].variants, m.variants,
                "single tenant: aggregate = tenant row"
            );
            assert_eq!((m.depth, m.in_flight), (0, 0), "tables drain to zero");
            let dispatched = eng.created_count();
            ing.stop();
            d.shutdown();
            (misses, m.variants, dispatched)
        };

        let (fixed_misses, fixed_counts, fixed_calls) = run("fixed-large");
        let (jit_misses, jit_counts, jit_calls) = run("jit");
        let total = |c: &[(String, u64)]| c.iter().map(|(_, n)| n).sum::<u64>();

        // Pinned: every dispatch lands on `large`, and all 4 tight
        // requests (4.4 s of service against a 1 s deadline) miss.
        assert_eq!(fixed_calls, 10);
        assert_eq!(total(&fixed_counts), 10, "counters sum to total dispatches");
        assert_eq!(fixed_counts.iter().find(|(n, _)| n == "large").unwrap().1, 10);
        assert_eq!(fixed_misses, 4);

        // JIT: identical load, per-call decisions.
        assert_eq!(jit_calls, 10);
        assert_eq!(total(&jit_counts), 10, "counters sum to total dispatches");
        assert!(
            jit_counts.iter().find(|(n, _)| n == "fast").unwrap().1 >= 1,
            "jit must route negative-slack requests to the fast variant: {jit_counts:?}"
        );
        assert!(
            jit_misses < fixed_misses,
            "jit ({jit_misses} misses) must strictly beat fixed-large ({fixed_misses} misses)"
        );
    }
}
