//! Identifier newtypes used across the NALAR runtime.
//!
//! Sessions, requests and futures follow the paper's terminology (§2
//! footnotes): a *request* is a single user inference request entering a
//! workflow; a *session* is a series of requests sharing context (e.g. a
//! chat); a *future* is the coordination handle for one agent/tool call.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

macro_rules! id_u64 {
    ($(#[$doc:meta])* $name:ident, $prefix:literal) => {
        $(#[$doc])*
        #[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
        pub struct $name(pub u64);

        impl fmt::Debug for $name {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, concat!($prefix, "{}"), self.0)
            }
        }
        impl fmt::Display for $name {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, concat!($prefix, "{}"), self.0)
            }
        }
    };
}

id_u64!(
    /// A user session: multiple requests sharing context (chat history, KV caches).
    SessionId, "s"
);
id_u64!(
    /// One user request entering a workflow driver.
    RequestId, "r"
);
id_u64!(
    /// One agent/tool invocation's coordination handle.
    FutureId, "f"
);
id_u64!(
    /// A tenant sharing the serving front door: an index into the
    /// deployment's `ingress.tenants` table, stamped on every request at
    /// admission (`ingress::SubmitRequest::tenant`). Tenancy is a
    /// front-door concept — weighted-fair queueing and per-tenant token
    /// buckets key on it — so requests below the ingress layer carry it
    /// only through their `RequestId`.
    TenantId, "t"
);

/// An emulated node of the cluster (owns a node store + instances).
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

impl fmt::Debug for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "n{}", self.0)
    }
}
impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "n{}", self.0)
    }
}

/// Agent/tool type name (e.g. `"developer"`). Cheap to clone.
#[derive(Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AgentType(pub Arc<str>);

impl AgentType {
    pub fn new(name: &str) -> Self {
        AgentType(Arc::from(name))
    }
    pub fn as_str(&self) -> &str {
        &self.0
    }
}
impl From<&str> for AgentType {
    fn from(s: &str) -> Self {
        AgentType::new(s)
    }
}
impl fmt::Debug for AgentType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl fmt::Display for AgentType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

/// A concrete agent instance: `agent_type:index` pinned to a node.
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct InstanceId {
    pub agent: AgentType,
    pub index: u32,
}

impl InstanceId {
    pub fn new(agent: impl Into<AgentType>, index: u32) -> Self {
        InstanceId { agent: agent.into(), index }
    }
}
impl fmt::Debug for InstanceId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}", self.agent, self.index)
    }
}
impl fmt::Display for InstanceId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}", self.agent, self.index)
    }
}

/// Where a controller lives: an agent instance or a workflow driver
/// (drivers are addressed per request). Futures' `creator`/`consumers`
/// metadata (paper Table 3) are `Location`s.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub enum Location {
    Instance(InstanceId),
    Driver(RequestId),
    Global,
}

impl fmt::Display for Location {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Location::Instance(i) => write!(f, "{i}"),
            Location::Driver(r) => write!(f, "driver[{r}]"),
            Location::Global => write!(f, "global"),
        }
    }
}

/// Monotonic id generator shared by a deployment.
#[derive(Default)]
pub struct IdGen {
    session: AtomicU64,
    request: AtomicU64,
    future: AtomicU64,
}

impl IdGen {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn session(&self) -> SessionId {
        SessionId(self.session.fetch_add(1, Ordering::Relaxed))
    }
    pub fn request(&self) -> RequestId {
        RequestId(self.request.fetch_add(1, Ordering::Relaxed))
    }
    pub fn future(&self) -> FutureId {
        FutureId(self.future.fetch_add(1, Ordering::Relaxed))
    }

    /// Advance every counter past the given high-water marks (journal
    /// replay: ids observed in the log must never be re-minted for fresh
    /// work, or a replayed request and a new one would collide in the
    /// future index / trace registry). Monotonic — a stale plan can
    /// never move a counter backwards.
    pub fn advance_past(&self, session: u64, request: u64, future: u64) {
        self.session.fetch_max(session + 1, Ordering::Relaxed);
        self.request.fetch_max(request + 1, Ordering::Relaxed);
        self.future.fetch_max(future + 1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_forms() {
        assert_eq!(SessionId(3).to_string(), "s3");
        assert_eq!(InstanceId::new("dev", 2).to_string(), "dev:2");
        assert_eq!(
            Location::Instance(InstanceId::new("dev", 0)).to_string(),
            "dev:0"
        );
    }

    #[test]
    fn idgen_monotonic_unique() {
        let g = IdGen::new();
        let a = g.future();
        let b = g.future();
        assert!(b.0 > a.0);
    }

    #[test]
    fn advance_past_never_reminting_replayed_ids() {
        let g = IdGen::new();
        g.advance_past(10, 20, 30);
        assert_eq!(g.session().0, 11);
        assert_eq!(g.request().0, 21);
        assert_eq!(g.future().0, 31);
        // monotonic: a stale (lower) plan cannot rewind the counters
        g.advance_past(0, 0, 0);
        assert_eq!(g.request().0, 22);
    }

    #[test]
    fn agent_type_cheap_clone_eq() {
        let a = AgentType::new("planner");
        let b = a.clone();
        assert_eq!(a, b);
        assert_eq!(a.as_str(), "planner");
    }
}
