"""L1 Pallas attention kernels (flash prefill + cached decode).

These are the compute hot-spot of NALAR's LLM agents. The paper's serving
testbed uses CUDA GPUs via vLLM; per the hardware-adaptation rule we rethink
the flash-attention structure for the TPU model instead of porting CUDA
idioms:

* the HBM<->VMEM schedule that CUDA expresses with threadblocks + shared
  memory is expressed here with a Pallas ``grid`` over (batch, head,
  q-block) and ``BlockSpec`` index maps — each program instance sees one q
  tile in VMEM-resident refs while K/V are streamed block-by-block;
* the online-softmax accumulator (running max ``m``, denominator ``l``,
  weighted sum ``acc``) keeps the live footprint at O(BLOCK_Q * Dh) instead
  of O(T^2) — the core flash-attention insight, expressed as VMEM tiling;
* matmuls are shaped for the MXU systolic array
  (``[BLOCK_Q, Dh] x [Dh, BLOCK_K]``), accumulating in f32 regardless of
  the input dtype (bf16 inputs supported).

Kernels are lowered with ``interpret=True`` — the CPU PJRT plugin cannot run
Mosaic custom-calls; real-TPU perf is estimated analytically in
EXPERIMENTS.md §Perf from :func:`vmem_footprint_bytes` and
:func:`mxu_utilization_estimate`.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30

# Default tile sizes. One program's q/k/v tiles plus f32 accumulators must
# fit the ~16 MiB VMEM budget; see vmem_footprint_bytes().
DEFAULT_BLOCK_Q = 32
DEFAULT_BLOCK_K = 32


def _prefill_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, block_q, block_k, t_total):
    """One (batch, head, q-block) program of causal flash attention.

    Refs (shapes after BlockSpec slicing):
      len_ref: [B]                 per-batch valid lengths (full array)
      q_ref:   [1, 1, block_q, dh] the q tile for this program
      k_ref:   [1, 1, t, dh]       full K for this (batch, head)
      v_ref:   [1, 1, t, dh]       full V for this (batch, head)
      o_ref:   [1, 1, block_q, dh] output tile
    """
    b = pl.program_id(0)
    qi = pl.program_id(2)
    length = len_ref[b]
    q = q_ref[0, 0].astype(jnp.float32)  # [block_q, dh]
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=jnp.float32))
    q = q * scale

    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)  # absolute q rows

    def body(kb, carry):
        acc, m, l = carry
        k_start = kb * block_k
        k_blk = jax.lax.dynamic_slice_in_dim(k_ref[0, 0], k_start, block_k, axis=0).astype(jnp.float32)
        v_blk = jax.lax.dynamic_slice_in_dim(v_ref[0, 0], k_start, block_k, axis=0).astype(jnp.float32)
        k_pos = k_start + jax.lax.iota(jnp.int32, block_k)
        s = q @ k_blk.T  # [block_q, block_k] — MXU-shaped
        mask = (k_pos[None, :] <= q_pos[:, None]) & (k_pos[None, :] < length)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + p @ v_blk
        return acc_new, m_new, l_new

    # Causality: rows in this q tile never see keys past the tile's last row,
    # so only stream k blocks up to that point (ceil: a partial block is
    # still needed when block_q < block_k; the mask trims the overshoot).
    n_kblocks = (jnp.minimum((qi + 1) * block_q, t_total) + block_k - 1) // block_k
    init = (
        jnp.zeros((block_q, dh), jnp.float32),
        jnp.full((block_q,), NEG_INF, jnp.float32),
        jnp.zeros((block_q,), jnp.float32),
    )
    acc, m, l = jax.lax.fori_loop(0, n_kblocks, body, init)
    l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows (pad region) -> zeros
    o_ref[0, 0] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_attention_prefill(q, k, v, length, *, block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K):
    """Batched causal flash attention.

    Args:
      q, k, v: ``[B, H, T, Dh]``; ``T`` must be divisible by the block sizes
               (they are shrunk to ``T`` if larger).
      length:  ``[B]`` int32 — valid token count per batch element; keys at
               positions ``>= length[b]`` are masked.

    Returns ``[B, H, T, Dh]``, matching a vmapped
    :func:`ref.attention_prefill_ref`.
    """
    b, h, t, dh = q.shape
    block_q = min(block_q, t)
    block_k = min(block_k, t)
    if t % block_q or t % block_k:
        raise ValueError(f"T={t} not tileable by ({block_q},{block_k})")
    grid = (b, h, t // block_q)
    kernel = functools.partial(_prefill_kernel, block_q=block_q, block_k=block_k, t_total=t)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b,), lambda bb, hh, qq: (0,)),
            pl.BlockSpec((1, 1, block_q, dh), lambda bb, hh, qq: (bb, hh, qq, 0)),
            pl.BlockSpec((1, 1, t, dh), lambda bb, hh, qq: (bb, hh, 0, 0)),
            pl.BlockSpec((1, 1, t, dh), lambda bb, hh, qq: (bb, hh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh), lambda bb, hh, qq: (bb, hh, qq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, t, dh), q.dtype),
        interpret=True,
    )(length, q, k, v)


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *, block_k, s_total):
    """One (batch, head) program of single-position decode attention.

    Refs: pos_ref [B]; q_ref [1, 1, 1, dh]; k_ref/v_ref [1, 1, s, dh];
    o_ref [1, 1, 1, dh].
    """
    b = pl.program_id(0)
    pos = pos_ref[b]
    q = q_ref[0, 0].astype(jnp.float32)  # [1, dh]
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=jnp.float32))
    q = q * scale

    def body(kb, carry):
        acc, m, l = carry
        k_start = kb * block_k
        k_blk = jax.lax.dynamic_slice_in_dim(k_ref[0, 0], k_start, block_k, axis=0).astype(jnp.float32)
        v_blk = jax.lax.dynamic_slice_in_dim(v_ref[0, 0], k_start, block_k, axis=0).astype(jnp.float32)
        k_pos = k_start + jax.lax.iota(jnp.int32, block_k)
        s = (q @ k_blk.T)[0]  # [block_k]
        s = jnp.where(k_pos <= pos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max())
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum()
        acc_new = acc * alpha + p @ v_blk
        return acc_new, m_new, l_new

    # Only stream K/V blocks that can contain positions <= pos.
    n_kblocks = jnp.minimum(pos // block_k + 1, s_total // block_k)
    init = (jnp.zeros((dh,), jnp.float32), jnp.float32(NEG_INF), jnp.float32(0.0))
    acc, m, l = jax.lax.fori_loop(0, n_kblocks, body, init)
    l = jnp.where(l == 0.0, 1.0, l)
    o_ref[0, 0] = (acc / l)[None, :].astype(o_ref.dtype)


def decode_attention(q, k, v, pos, *, block_k=DEFAULT_BLOCK_K):
    """Batched cached decode attention.

    Args:
      q:    ``[B, H, Dh]`` query at position ``pos[b]`` per batch element.
      k, v: ``[B, H, S, Dh]`` KV caches; ``S`` divisible by ``block_k``.
      pos:  ``[B]`` int32 current positions (attends to ``0..=pos[b]``).

    Returns ``[B, H, Dh]``, matching a vmapped
    :func:`ref.attention_decode_ref`.
    """
    b, h, s, dh = k.shape
    block_k = min(block_k, s)
    if s % block_k:
        raise ValueError(f"S={s} not tileable by block_k={block_k}")
    grid = (b, h)
    kernel = functools.partial(_decode_kernel, block_k=block_k, s_total=s)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b,), lambda bb, hh: (0,)),
            pl.BlockSpec((1, 1, 1, dh), lambda bb, hh: (bb, hh, 0, 0)),
            pl.BlockSpec((1, 1, s, dh), lambda bb, hh: (bb, hh, 0, 0)),
            pl.BlockSpec((1, 1, s, dh), lambda bb, hh: (bb, hh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, dh), lambda bb, hh: (bb, hh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, 1, dh), q.dtype),
        interpret=True,
    )(pos, q[:, :, None, :], k, v)
    return out[:, :, 0, :]


def vmem_footprint_bytes(block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K, dh=16, t=128, dtype_bytes=4):
    """Analytic VMEM footprint of one prefill program instance.

    q tile + full-head K/V (streamed view) + output tile + f32 accumulators.
    Used by EXPERIMENTS.md §Perf to justify tile sizes against a ~16 MiB
    VMEM budget.
    """
    tiles = (block_q + 2 * t + block_q) * dh * dtype_bytes
    acc = block_q * dh * 4 + 2 * block_q * 4
    return tiles + acc


def mxu_utilization_estimate(block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K, dh=16):
    """Fraction of idealized 128x128x128 MXU passes kept busy by the two
    matmuls of one inner step. Structural estimate only (interpret mode has
    no hardware counters)."""
    busy = 2 * block_q * dh * block_k  # QK^T + PV multiply-accumulates
    passes_qk = -(-block_q // 128) * -(-block_k // 128) * -(-dh // 128)
    passes_pv = -(-block_q // 128) * -(-dh // 128) * -(-block_k // 128)
    ideal = (passes_qk + passes_pv) * 128 ** 3
    return busy / ideal
