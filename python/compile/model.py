"""L2: the JAX transformer LM served by NALAR's LLM agents.

A small byte-level decoder-only transformer with three AOT entry points:

* :func:`prefill` — ``(params, tokens[B,T], length[B]) -> (logits[B,V], kv)``
* :func:`decode`  — ``(params, token[B], pos[B], kv) -> (logits[B,V], kv')``
* :func:`embed`   — ``(params, tokens[B,T], length[B]) -> [B,D]`` mean-pooled
  hidden states, used by the Rust vector store (ChromaDB substitute).

Attention runs through the L1 Pallas kernels
(:mod:`compile.kernels.attention`), so the kernels lower into the same HLO
the Rust runtime executes. The KV cache is an explicit input/output
(``[L, 2, B, H, S, Dh]``) so the Rust engine owns cache placement — that
ownership is what NALAR's K,V-cache policy layer (paper §4.3.2) controls.

Weights are *runtime inputs* (not baked constants): ``aot.py`` writes them
to ``artifacts/params.bin`` and the Rust runtime feeds them as leading
arguments. This keeps the HLO text small and lets one artifact serve any
checkpoint with the same architecture.
"""

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .kernels.attention import decode_attention, flash_attention_prefill


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters of the served LM."""

    vocab: int = 259  # 256 bytes + BOS(256) + EOS(257) + PAD(258)
    d_model: int = 64
    n_heads: int = 4
    head_dim: int = 16
    n_layers: int = 2
    d_ff: int = 256
    max_seq: int = 128

    BOS: int = field(default=256, init=False)
    EOS: int = field(default=257, init=False)
    PAD: int = field(default=258, init=False)


# Deterministic parameter order — the contract between aot.py (which writes
# params.bin) and the Rust runtime (which feeds them as leading inputs).
def param_spec(cfg: ModelConfig):
    """Yield ``(name, shape)`` for every weight, in AOT argument order."""
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.n_heads * cfg.head_dim
    yield "tok_emb", (cfg.vocab, d)
    yield "pos_emb", (cfg.max_seq, d)
    for i in range(cfg.n_layers):
        yield f"l{i}.ln1", (d,)
        yield f"l{i}.wq", (d, hd)
        yield f"l{i}.wk", (d, hd)
        yield f"l{i}.wv", (d, hd)
        yield f"l{i}.wo", (hd, d)
        yield f"l{i}.ln2", (d,)
        yield f"l{i}.w1", (d, f)
        yield f"l{i}.w2", (f, d)
    yield "ln_f", (d,)


def init_params(cfg: ModelConfig, seed: int = 0):
    """Deterministic scaled-gaussian init; returns ``{name: array}``."""
    key = jax.random.PRNGKey(seed)
    params = {}
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2")) or name == "ln_f":
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else 1
            params[name] = (
                jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(jnp.float32(fan_in))
            )
    return params


def _rms_norm(x, w, eps=1e-6):
    return x * w * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)


def _split_heads(x, cfg):
    # [..., H*Dh] -> [..., H, Dh] -> heads-leading
    *lead, _ = x.shape
    return x.reshape(*lead, cfg.n_heads, cfg.head_dim)


def _trunk_prefill(params, tokens, length, cfg: ModelConfig, use_pallas=True):
    """Shared transformer trunk over a full (padded) sequence.

    Returns final hidden states ``[B, T, D]`` and per-layer K/V stacked as
    ``[L, 2, B, H, T, Dh]``.
    """
    b, t = tokens.shape
    x = params["tok_emb"][tokens] + params["pos_emb"][None, :t, :]
    kvs = []
    for i in range(cfg.n_layers):
        h = _rms_norm(x, params[f"l{i}.ln1"])
        q = _split_heads(h @ params[f"l{i}.wq"], cfg).transpose(0, 2, 1, 3)  # [B,H,T,Dh]
        k = _split_heads(h @ params[f"l{i}.wk"], cfg).transpose(0, 2, 1, 3)
        v = _split_heads(h @ params[f"l{i}.wv"], cfg).transpose(0, 2, 1, 3)
        if use_pallas:
            attn = flash_attention_prefill(q, k, v, length)
        else:
            from .kernels.ref import attention_prefill_ref

            attn = jax.vmap(attention_prefill_ref)(q, k, v, length)
        attn = attn.transpose(0, 2, 1, 3).reshape(b, t, -1)
        x = x + attn @ params[f"l{i}.wo"]
        h2 = _rms_norm(x, params[f"l{i}.ln2"])
        x = x + jax.nn.gelu(h2 @ params[f"l{i}.w1"]) @ params[f"l{i}.w2"]
        kvs.append(jnp.stack([k, v]))  # [2, B, H, T, Dh]
    return x, jnp.stack(kvs)  # [L, 2, B, H, T, Dh]


def prefill(params, tokens, length, cfg: ModelConfig, use_pallas=True):
    """Prefill a padded prompt batch.

    Args:
      tokens: ``[B, T]`` int32, padded with ``cfg.PAD`` past ``length[b]``.
      length: ``[B]`` int32 valid lengths (>=1).

    Returns:
      ``(logits[B, vocab], kv[L, 2, B, H, T, Dh])`` — logits for the *next*
      token after position ``length[b]-1``.
    """
    x, kv = _trunk_prefill(params, tokens, length, cfg, use_pallas)
    x = _rms_norm(x, params["ln_f"])
    last = jnp.take_along_axis(x, (length - 1)[:, None, None], axis=1)[:, 0, :]  # [B, D]
    logits = last @ params["tok_emb"].T
    return logits, kv


def decode(params, token, pos, kv, cfg: ModelConfig, use_pallas=True):
    """One decode step over an explicit KV cache.

    Args:
      token: ``[B]`` int32 current tokens (at position ``pos[b]``).
      pos:   ``[B]`` int32 positions in ``0..max_seq``.
      kv:    ``[L, 2, B, H, S, Dh]`` cache; positions ``> pos`` are stale.

    Returns ``(logits[B, vocab], kv')`` with the new K/V written at ``pos``.
    """
    b = token.shape[0]
    x = params["tok_emb"][token] + params["pos_emb"][pos]  # [B, D]
    new_kv = []
    for i in range(cfg.n_layers):
        h = _rms_norm(x, params[f"l{i}.ln1"])
        q = _split_heads(h @ params[f"l{i}.wq"], cfg)  # [B, H, Dh]
        k_new = _split_heads(h @ params[f"l{i}.wk"], cfg)
        v_new = _split_heads(h @ params[f"l{i}.wv"], cfg)

        def write(cache, new, p):
            # cache [H, S, Dh], new [H, Dh] -> write row at position p
            return jax.lax.dynamic_update_slice(cache, new[:, None, :], (0, p, 0))

        k_cache = jax.vmap(write)(kv[i, 0], k_new, pos)  # [B, H, S, Dh]
        v_cache = jax.vmap(write)(kv[i, 1], v_new, pos)
        if use_pallas:
            attn = decode_attention(q, k_cache, v_cache, pos)  # [B, H, Dh]
        else:
            from .kernels.ref import attention_decode_ref

            attn = jax.vmap(attention_decode_ref)(q, k_cache, v_cache, pos)
        x = x + attn.reshape(b, -1) @ params[f"l{i}.wo"]
        h2 = _rms_norm(x, params[f"l{i}.ln2"])
        x = x + jax.nn.gelu(h2 @ params[f"l{i}.w1"]) @ params[f"l{i}.w2"]
        new_kv.append(jnp.stack([k_cache, v_cache]))
    x = _rms_norm(x, params["ln_f"])
    logits = x @ params["tok_emb"].T
    return logits, jnp.stack(new_kv)


def embed(params, tokens, length, cfg: ModelConfig, use_pallas=True):
    """Mean-pooled final hidden states for retrieval (``[B, D]``, L2-normed)."""
    x, _ = _trunk_prefill(params, tokens, length, cfg, use_pallas)
    t = tokens.shape[1]
    mask = (jnp.arange(t)[None, :] < length[:, None]).astype(jnp.float32)
    pooled = (x * mask[:, :, None]).sum(axis=1) / length[:, None].astype(jnp.float32)
    return pooled / jnp.linalg.norm(pooled, axis=-1, keepdims=True).clip(1e-6)


def flat_params(params, cfg: ModelConfig):
    """Weights as a list in :func:`param_spec` order (AOT argument order)."""
    return [params[name] for name, _ in param_spec(cfg)]
