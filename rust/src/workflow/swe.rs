//! Software-engineering workflow (paper Fig. 1 / Fig. 4, §6, Fig. 9c).
//!
//! The Fig. 4 driver, faithfully: a planner decomposes the request into
//! subtasks; each subtask goes to a developer agent (documentation lookup
//! feeding the implementation), whose output runs through the test
//! harness; failed subtasks are *relaunched by the driver* — the
//! fine-grained retry loop that makes the workflow recursive and load
//! non-deterministic.
//!
//! Written as a resumable [`Driver`]: the retry loop suspends on the set
//! of outstanding test futures (`Pending { waiting_on }`) instead of
//! spinning `try_value` with a sleep, so a relaunch costs one wakeup, not
//! a polling thread.

use std::time::Duration;

use crate::error::{Error, Result};
use crate::futures::{FutureHandle, Value};
use crate::ids::FutureId;
use crate::json;
use crate::workflow::driver::{drive_blocking, Driver, Step};
use crate::workflow::Env;

const MAX_RETRIES: u32 = 3;

struct SubtaskRun {
    test: FutureHandle,
    attempt: u32,
}

/// One coding request through plan -> implement -> test -> (retry).
/// Blocking compat shim over [`SweDriver`].
pub fn run(env: &Env, input: &Value, timeout: Duration) -> Result<Value> {
    drive_blocking(&mut SweDriver::new(input), env, timeout)
}

/// The Fig. 4 retry loop's live state: one entry per subtask.
struct Work {
    runs: Vec<SubtaskRun>,
    done: Vec<bool>,
    total_attempts: u32,
}

enum State {
    Start,
    /// #1 — planner decomposes the request (Fig. 4 lines 9-12: we suspend
    /// on the plan because the subtask count is data-dependent).
    Plan { plan: FutureHandle },
    /// #2/#3 — subtasks in flight; failures relaunch in place.
    Loop(Work),
    /// Journal-replay re-entry point ([`SweDriver::restore`]): completed
    /// subtasks stay banked; the first poll relaunches only the
    /// unfinished ones at their recorded attempt counts.
    Resume { done: Vec<bool>, attempts: Vec<u32>, total: u32 },
    Finished,
}

/// See [`run`]; resumable form.
pub struct SweDriver {
    task: String,
    state: State,
}

impl SweDriver {
    pub fn new(input: &Value) -> SweDriver {
        SweDriver {
            task: input.get("task").as_str().unwrap_or("fix the bug").to_string(),
            state: State::Start,
        }
    }

    /// Rebuild a driver from a [`Driver::serialize_state`] snapshot. A
    /// planning-stage (or unrecognized) snapshot restarts from `Start`;
    /// a loop snapshot keeps passed subtasks done and relaunches only the
    /// unfinished ones, each at its recorded attempt count so the retry
    /// budget (`MAX_RETRIES`) carries across the crash.
    pub fn restore(input: &Value, state: &Value) -> SweDriver {
        let mut d = SweDriver::new(input);
        if state.str_or("stage", "") == "loop" {
            if let Value::Arr(flags) = state.get("done") {
                let done: Vec<bool> =
                    flags.iter().map(|f| f.as_bool().unwrap_or(false)).collect();
                let attempts: Vec<u32> = match state.get("attempts") {
                    Value::Arr(a) => {
                        a.iter().map(|v| v.as_u64().unwrap_or(0) as u32).collect()
                    }
                    _ => vec![0; done.len()],
                };
                if !done.is_empty() {
                    let total = state.u64_or("total", done.len() as u64) as u32;
                    d.state = State::Resume { done, attempts, total };
                }
            }
        }
        d
    }

    /// Launch (or relaunch) one subtask: documentation lookup feeding the
    /// implementation, whose output feeds the test harness. A `retry`
    /// attempt re-enters the graph with a bumped `retry_count` — the LPT
    /// policy's signal.
    fn launch_subtask(
        &self,
        env: &Env,
        i: usize,
        attempt: u32,
        plan: Option<FutureId>,
    ) -> SubtaskRun {
        let deeper = env.ctx.deeper();
        let note = if attempt == 0 { String::new() } else { format!(" retry {attempt}") };
        let docs = deeper.agent("documentation").call(
            "get",
            json!({"query": format!("{} (part {i}{note})", self.task), "k": 2}),
        );
        let mut deps = vec![docs.id()];
        if let Some(plan) = plan {
            deps.insert(0, plan);
        }
        let code = deeper.agent("developer").call_with(
            "implement",
            json!({
                "prompt": format!("{} — subtask {i}{note}", self.task),
                "max_new_tokens": 160,
            }),
            &deps,
            attempt,
        );
        let test = deeper.agent("test_harness").call_with(
            "unit_test",
            json!({"code": format!("subtask-{i}"), "attempt": attempt}),
            &[code.id()],
            attempt,
        );
        SubtaskRun { test, attempt }
    }
}

impl Driver for SweDriver {
    fn poll(&mut self, env: &Env) -> Step {
        loop {
            match std::mem::replace(&mut self.state, State::Finished) {
                State::Start => {
                    let plan = env
                        .ctx
                        .agent("planner")
                        .call("plan", json!({"prompt": self.task.as_str(), "max_new_tokens": 48}));
                    self.state = State::Plan { plan };
                }
                State::Plan { plan } => match plan.try_value() {
                    None => {
                        let id = plan.id();
                        self.state = State::Plan { plan };
                        return Step::Pending { waiting_on: vec![id] };
                    }
                    Some(Err(e)) => return Step::Done(Err(e)),
                    Some(Ok(out)) => {
                        let plan_tokens = out.get("generated_tokens").as_u64().unwrap_or(8);
                        let n_subtasks = 2 + (plan_tokens % 3) as usize; // 2-4, model-driven
                        // #2 — launch every subtask in parallel (non-blocking).
                        let runs: Vec<SubtaskRun> = (0..n_subtasks)
                            .map(|i| self.launch_subtask(env, i, 0, Some(plan.id())))
                            .collect();
                        self.state = State::Loop(Work {
                            done: vec![false; n_subtasks],
                            total_attempts: n_subtasks as u32,
                            runs,
                        });
                    }
                },
                State::Loop(mut w) => {
                    // #3 — the Fig. 4 retry loop: consume every test that
                    // resolved, relaunch failures, then suspend on what is
                    // still outstanding.
                    let mut waiting: Vec<FutureId> = Vec::new();
                    for i in 0..w.runs.len() {
                        if w.done[i] {
                            continue;
                        }
                        let Some(result) = w.runs[i].test.try_value() else {
                            waiting.push(w.runs[i].test.id());
                            continue;
                        };
                        let passed = match result {
                            Ok(v) => v.get("result").as_str() == Some("Pass"),
                            Err(_) => false, // system error: driver retries (§5)
                        };
                        if passed {
                            w.done[i] = true;
                        } else {
                            let attempt = w.runs[i].attempt + 1;
                            if attempt > MAX_RETRIES {
                                return Step::Done(Err(Error::msg(format!(
                                    "failed to implement `{}` subtask {i} after \
                                     {MAX_RETRIES} retries",
                                    self.task
                                ))));
                            }
                            // relaunch just this subtask (re-enters the
                            // graph: the LPT policy's signal).
                            w.runs[i] = self.launch_subtask(env, i, attempt, None);
                            w.total_attempts += 1;
                            waiting.push(w.runs[i].test.id());
                        }
                    }
                    if w.done.iter().all(|d| *d) {
                        // #4 — merge.
                        return Step::Done(Ok(json!({
                            "task": self.task.as_str(),
                            "subtasks": w.runs.len(),
                            "attempts": w.total_attempts,
                        })));
                    }
                    self.state = State::Loop(w);
                    return Step::Pending { waiting_on: waiting };
                }
                State::Resume { done, attempts, total } => {
                    if done.iter().all(|d| *d) {
                        // Crash landed after the last test passed but
                        // before the merge was journaled terminal.
                        return Step::Done(Ok(json!({
                            "task": self.task.as_str(),
                            "subtasks": done.len(),
                            "attempts": total,
                        })));
                    }
                    // Relaunch only the unfinished subtasks (their
                    // pre-crash futures died with the node); passed slots
                    // keep a never-polled placeholder handle — the loop
                    // checks `done[i]` before touching `runs[i]`.
                    let fresh: Vec<(usize, SubtaskRun)> = (0..done.len())
                        .filter(|i| !done[*i])
                        .map(|i| {
                            let attempt = attempts.get(i).copied().unwrap_or(0);
                            (i, self.launch_subtask(env, i, attempt, None))
                        })
                        .collect();
                    let placeholder = fresh[0].1.test.clone();
                    let mut runs: Vec<SubtaskRun> = (0..done.len())
                        .map(|_| SubtaskRun { test: placeholder.clone(), attempt: 0 })
                        .collect();
                    for (i, run) in fresh {
                        runs[i] = run;
                    }
                    self.state = State::Loop(Work { runs, done, total_attempts: total });
                }
                State::Finished => {
                    return Step::Done(Err(Error::msg("swe driver polled after completion")))
                }
            }
        }
    }

    /// Planning is stage 1; the subtask loop counts completed subtasks on
    /// top, so a request with one test left outranks one that just
    /// planned (front-door SRTF).
    fn stage(&self) -> u32 {
        match &self.state {
            State::Start => 0,
            State::Plan { .. } => 1,
            State::Loop(w) => 2 + w.done.iter().filter(|d| **d).count() as u32,
            State::Resume { done, .. } => 2 + done.iter().filter(|d| **d).count() as u32,
            State::Finished => u32::MAX,
        }
    }

    fn serialize_state(&self) -> Value {
        match &self.state {
            // Planning resumes by re-planning: the subtask count is
            // derived from the plan output, which died with the node.
            State::Start | State::Plan { .. } => json!({"stage": "plan"}),
            State::Loop(w) => json!({
                "stage": "loop",
                "done": w.done.clone(),
                "attempts": w.runs.iter().map(|r| r.attempt).collect::<Vec<u32>>(),
                "total": w.total_attempts,
            }),
            State::Resume { done, attempts, total } => json!({
                "stage": "loop",
                "done": done.clone(),
                "attempts": attempts.clone(),
                "total": *total,
            }),
            State::Finished => Value::Null,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::Deployment;
    use crate::workflow::WorkflowKind;

    #[test]
    fn completes_with_retries() {
        let mut cfg = WorkflowKind::Swe.config();
        cfg.time_scale = 0.0005;
        let d = Deployment::launch(cfg).unwrap();
        let env = Env::new(&d, d.new_session());
        let out = run(
            &env,
            &json!({"task": "Enable OAuth login for the website"}),
            Duration::from_secs(30),
        )
        .unwrap();
        let subtasks = out.get("subtasks").as_u64().unwrap();
        let attempts = out.get("attempts").as_u64().unwrap();
        assert!((2..=4).contains(&subtasks));
        assert!(attempts >= subtasks, "attempts {attempts} < subtasks {subtasks}");
        d.shutdown();
    }

    #[test]
    fn retries_recorded_in_graph_metadata() {
        let mut cfg = WorkflowKind::Swe.config();
        cfg.time_scale = 0.0005;
        cfg.agents
            .iter_mut()
            .find(|a| a.name == "test_harness")
            .unwrap()
            .failure_rate = 0.9; // force retries
        let d = Deployment::launch(cfg).unwrap();
        let env = Env::new(&d, d.new_session());
        // may exhaust retries; both outcomes legal, but the future table
        // must contain retried futures either way
        let _ = run(&env, &json!({"task": "t"}), Duration::from_secs(30));
        let mut max_retry = 0;
        d.table().for_each(|c| {
            max_retry = max_retry.max(c.meta().retry_count);
        });
        assert!(max_retry >= 1, "no retried futures recorded");
        d.shutdown();
    }

    #[test]
    fn restore_relaunches_only_unfinished_subtasks() {
        let mut cfg = WorkflowKind::Swe.config();
        cfg.time_scale = 0.0005;
        let d = Deployment::launch(cfg).unwrap();
        let env = Env::new(&d, d.new_session());
        let input = json!({"task": "t"});
        // Two of three subtasks already passed before the crash. The
        // restored driver banks the two and drives only the last one
        // (still at attempt 0, full retry budget) to completion.
        let snap = json!({
            "stage": "loop",
            "done": [true, true, false],
            "attempts": [0, 1, 0],
            "total": 4,
        });
        let mut drv = SweDriver::restore(&input, &snap);
        assert_eq!(drv.stage(), 4, "2 banked subtasks on top of the loop base");
        let out = drive_blocking(&mut drv, &env, Duration::from_secs(30)).unwrap();
        assert_eq!(out.get("subtasks").as_u64(), Some(3));
        assert!(out.get("attempts").as_u64().unwrap() >= 4);
        // A snapshot whose every subtask passed completes without any
        // relaunch at all.
        let all_done = json!({"stage": "loop", "done": [true], "attempts": [0], "total": 1});
        let mut done_drv = SweDriver::restore(&input, &all_done);
        let out2 = drive_blocking(&mut done_drv, &env, Duration::from_secs(5)).unwrap();
        assert_eq!(out2.get("attempts").as_u64(), Some(1));
        d.shutdown();
    }
}
