//! Admission control at the serving front door.
//!
//! Open-loop traffic does not slow down when the system does — arrivals
//! keep coming, and something must give: either the queue (bounded
//! shedding), the arrival rate (token bucket), or latency (unbounded, the
//! baseline failure mode the §6 saturation sweep exposes). One
//! [`AdmissionController`] guards each workflow queue; its accept/shed
//! counters flow into [`crate::coordinator::IngressMetrics`] telemetry.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::config::IngressSettings;

/// How the front door decides accept-vs-shed at submit time.
#[derive(Debug, Clone)]
pub enum AdmissionPolicy {
    /// Accept everything. The queue absorbs overload and latency diverges
    /// instead — how every compared baseline behaves (§2.3).
    Unbounded,
    /// Shed when the target queue already holds `cap` requests: bounds
    /// both queue memory and worst-case queueing delay, and turns
    /// overload into fast, retryable rejections.
    Bounded { cap: usize },
    /// Token bucket: admit at most `rate` requests/second (wall clock),
    /// with bursts up to `burst` tokens.
    TokenBucket { rate: f64, burst: f64 },
}

impl AdmissionPolicy {
    /// Resolve the configured policy (`DeploymentConfig.ingress`).
    pub fn from_settings(s: &IngressSettings) -> AdmissionPolicy {
        match s.policy.as_str() {
            "unbounded" => AdmissionPolicy::Unbounded,
            "token_bucket" => AdmissionPolicy::TokenBucket {
                rate: if s.token_rate > 0.0 { s.token_rate } else { f64::INFINITY },
                burst: s.token_burst.max(1.0),
            },
            _ => AdmissionPolicy::Bounded { cap: s.queue_cap.max(1) },
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AdmissionPolicy::Unbounded => "unbounded",
            AdmissionPolicy::Bounded { .. } => "bounded",
            AdmissionPolicy::TokenBucket { .. } => "token_bucket",
        }
    }

    /// Queue cap this policy enforces (0 = unbounded).
    pub fn cap(&self) -> usize {
        match self {
            AdmissionPolicy::Bounded { cap } => *cap,
            _ => 0,
        }
    }
}

struct Bucket {
    tokens: f64,
    last: Instant,
}

/// Accept/shed decision state for one workflow queue.
pub struct AdmissionController {
    policy: AdmissionPolicy,
    bucket: Mutex<Bucket>,
    pub accepted: AtomicU64,
    pub shed: AtomicU64,
}

impl AdmissionController {
    pub fn new(policy: AdmissionPolicy) -> AdmissionController {
        let burst = match &policy {
            AdmissionPolicy::TokenBucket { burst, .. } => *burst,
            _ => 0.0,
        };
        AdmissionController {
            policy,
            bucket: Mutex::new(Bucket { tokens: burst, last: Instant::now() }),
            accepted: AtomicU64::new(0),
            shed: AtomicU64::new(0),
        }
    }

    pub fn policy(&self) -> &AdmissionPolicy {
        &self.policy
    }

    /// Decide for one request given the current queue `depth`. Updates the
    /// accept/shed counters; `Err` carries the shed reason.
    pub fn admit(&self, depth: usize) -> Result<(), String> {
        self.admit_at(depth, Instant::now())
    }

    /// [`Self::admit`] against an explicit `now` — the deterministic
    /// entry point for property tests driving the token bucket with a
    /// virtual clock ([`crate::testkit::Clock`]): refill becomes a pure
    /// function of the timestamps the test chooses. Time never runs
    /// backwards (an older `now` refills nothing).
    pub fn admit_at(&self, depth: usize, now: Instant) -> Result<(), String> {
        let verdict = match &self.policy {
            AdmissionPolicy::Unbounded => Ok(()),
            AdmissionPolicy::Bounded { cap } => {
                if depth >= *cap {
                    Err(format!("queue full ({depth}/{cap})"))
                } else {
                    Ok(())
                }
            }
            AdmissionPolicy::TokenBucket { rate, burst } => {
                let mut b = self.bucket.lock().unwrap();
                let refill = now.saturating_duration_since(b.last).as_secs_f64() * rate;
                b.tokens = (b.tokens + refill).min(*burst);
                b.last = b.last.max(now);
                if b.tokens >= 1.0 {
                    b.tokens -= 1.0;
                    Ok(())
                } else {
                    Err(format!("rate limit ({rate:.1} rps)"))
                }
            }
        };
        match &verdict {
            Ok(()) => self.accepted.fetch_add(1, Ordering::Relaxed),
            Err(_) => self.shed.fetch_add(1, Ordering::Relaxed),
        };
        verdict
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_settings_resolves_names() {
        let mut s = IngressSettings::default();
        assert!(matches!(AdmissionPolicy::from_settings(&s), AdmissionPolicy::Bounded { .. }));
        s.policy = "unbounded".into();
        assert!(matches!(AdmissionPolicy::from_settings(&s), AdmissionPolicy::Unbounded));
        s.policy = "token_bucket".into();
        s.token_rate = 10.0;
        assert!(matches!(
            AdmissionPolicy::from_settings(&s),
            AdmissionPolicy::TokenBucket { .. }
        ));
    }

    #[test]
    fn unbounded_accepts_any_depth() {
        let c = AdmissionController::new(AdmissionPolicy::Unbounded);
        for depth in [0, 10, 100_000] {
            assert!(c.admit(depth).is_ok());
        }
        assert_eq!(c.accepted.load(Ordering::Relaxed), 3);
        assert_eq!(c.shed.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn bounded_sheds_at_cap() {
        let c = AdmissionController::new(AdmissionPolicy::Bounded { cap: 4 });
        assert!(c.admit(3).is_ok());
        let err = c.admit(4).unwrap_err();
        assert!(err.contains("queue full"), "{err}");
        assert!(c.admit(5).is_err());
        assert_eq!(c.accepted.load(Ordering::Relaxed), 1);
        assert_eq!(c.shed.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn token_bucket_enforces_burst_then_rate() {
        // negligible refill rate: only the initial burst admits
        let c = AdmissionController::new(AdmissionPolicy::TokenBucket { rate: 1e-9, burst: 2.0 });
        assert!(c.admit(0).is_ok());
        assert!(c.admit(0).is_ok());
        let err = c.admit(0).unwrap_err();
        assert!(err.contains("rate limit"), "{err}");
    }
}
