//! Micro/macro benchmark harness (criterion substitute).
//!
//! `cargo bench` targets are plain binaries (`harness = false`); each uses
//! these helpers: warmup + timed iterations with mean/p50/p95/p99, and an
//! aligned table printer for the paper-figure reproductions. The figure
//! reproductions themselves live in [`crate::bench`], which layers a
//! machine-readable report (`BENCH_*.json`) on top of these primitives.

use std::time::{Duration, Instant};

/// Result of a timed micro-benchmark.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub iters: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Timing {
    pub fn per_iter_ns(&self) -> f64 {
        self.mean.as_nanos() as f64
    }
}

impl std::fmt::Display for Timing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {:>10} p50 {:>10} p95 {:>10} p99 {:>10} (n={})",
            fmt_dur(self.mean),
            fmt_dur(self.p50),
            fmt_dur(self.p95),
            fmt_dur(self.p99),
            self.iters
        )
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.2}s", ns as f64 / 1e9)
    }
}

/// Nearest-rank quantile over a **sorted** slice (`p` in `[0, 1]`).
pub fn quantile_sorted(sorted: &[Duration], p: f64) -> Duration {
    if sorted.is_empty() {
        return Duration::ZERO;
    }
    let idx = (((sorted.len() - 1) as f64) * p).round() as usize;
    sorted[idx]
}

/// Time `f` for ~`budget` (after `warmup` iterations); per-iteration stats.
pub fn bench<F: FnMut()>(name: &str, warmup: u64, budget: Duration, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget || samples.len() < 10 {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
        if samples.len() >= 5_000_000 {
            break;
        }
    }
    let t = summarize(&mut samples);
    println!("  {name:<44} {t}");
    t
}

/// Summarize a set of duration samples.
pub fn summarize(samples: &mut [Duration]) -> Timing {
    samples.sort();
    let n = samples.len().max(1);
    let total: Duration = samples.iter().sum();
    Timing {
        iters: n as u64,
        mean: total / n as u32,
        p50: quantile_sorted(samples, 0.50),
        p95: quantile_sorted(samples, 0.95),
        p99: quantile_sorted(samples, 0.99),
        min: samples.first().copied().unwrap_or_default(),
        max: samples.last().copied().unwrap_or_default(),
    }
}

/// Aligned table printer for figure/table reproductions.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", widths.iter().map(|w| "-".repeat(*w + 2)).collect::<String>());
        for row in &self.rows {
            line(row);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let t = bench("noop-ish", 5, Duration::from_millis(20), || {
            std::hint::black_box(1 + 1);
        });
        assert!(t.iters >= 10);
        assert!(t.min <= t.p50 && t.p50 <= t.p95 && t.p95 <= t.p99 && t.p99 <= t.max);
    }

    #[test]
    fn fmt_dur_units() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500ns");
        assert!(fmt_dur(Duration::from_micros(1500)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).ends_with('s'));
    }

    #[test]
    fn quantiles_on_sorted_samples() {
        let xs: Vec<Duration> = (1..=100u64).map(Duration::from_millis).collect();
        assert_eq!(quantile_sorted(&xs, 0.0), Duration::from_millis(1));
        assert_eq!(quantile_sorted(&xs, 1.0), Duration::from_millis(100));
        assert!(quantile_sorted(&xs, 0.95) >= quantile_sorted(&xs, 0.50));
        assert_eq!(quantile_sorted(&[], 0.5), Duration::ZERO);
    }

    #[test]
    #[should_panic]
    fn table_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".to_string()]);
    }
}
