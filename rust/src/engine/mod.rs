//! Continuous-batching LLM engine — the vLLM substitute (DESIGN.md §3).
//!
//! Each LLM agent instance owns one engine core and drives it from its
//! event loop: `admit` new generation requests at any time, `step` advances
//! every active sequence by one token (continuous batching — new arrivals
//! join between steps, finished sequences leave). Two cores:
//!
//! * [`PjrtCore`] — real compute: byte-level tokenizer + the AOT transformer
//!   through [`crate::runtime::PjrtModel`]. Session KV caches are kept
//!   per-sequence and re-entered into the batch on continuation, managed by
//!   [`crate::state::kvcache::KvCacheManager`] (hit = incremental decode of
//!   the new prompt; miss = full re-prefill — exactly the recompute penalty
//!   the paper's KV policy avoids).
//! * [`SimCore`] — profiled latency model (calibrated against the PJRT
//!   path) for the rate-sweep benches, mirroring the paper's own use of
//!   emulation in §6.3. Identical interface, identical KV accounting.

pub mod pjrt_core;
pub mod sim;
pub mod tokenizer;

pub use pjrt_core::PjrtCore;
pub use sim::SimCore;
pub use tokenizer::Tokenizer;

use std::sync::Arc;

use crate::error::Result;
use crate::ids::SessionId;
use crate::state::kvcache::KvCacheManager;

/// A generation request admitted to an engine.
#[derive(Debug, Clone)]
pub struct EngineReq {
    /// Correlates the completion with the future being served.
    pub tag: u64,
    pub session: SessionId,
    pub prompt: String,
    /// Session history length in tokens (0 for fresh sessions). On a KV
    /// hit the history is *not* recomputed; on a miss it is.
    pub history_tokens: usize,
    pub max_new_tokens: usize,
    /// Model variant serving this call (JIT routing, DESIGN.md §13);
    /// `None` = the agent's profile curve as written.
    pub variant: Option<String>,
    /// The chosen variant's service-time multiplier (1.0 unrouted): the
    /// sim core scales prefill cost and decode throughput by it.
    pub latency_mult: f64,
}

/// Completion payload.
#[derive(Debug, Clone)]
pub struct GenOut {
    pub text: String,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    /// "hit" | "promoted" | "miss" — KV residency at admission.
    pub kv_outcome: &'static str,
}

/// A finished sequence handed back from `step`.
pub struct EngineDone {
    pub tag: u64,
    pub session: SessionId,
    pub result: Result<GenOut>,
}

/// The engine interface the agent instance drives.
pub trait EngineCore: Send {
    /// Accept a request (prefill happens on the next `step`).
    fn admit(&mut self, req: EngineReq);
    /// Advance all active sequences one token; returns completions.
    /// Blocking: real compute (pjrt) or modeled step time (sim).
    fn step(&mut self) -> Vec<EngineDone>;
    /// Sequences currently generating (admitted and unfinished).
    fn active(&self) -> usize;
    /// Largest batch the core can decode at once.
    fn max_batch(&self) -> usize;
    /// The tiered KV manager (policy hooks live here).
    fn kv_manager(&self) -> &Arc<KvCacheManager>;
    /// Drop a session's engine-side state (session end / migration out).
    fn evict_session(&mut self, session: SessionId);
}
