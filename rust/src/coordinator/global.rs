//! The global controller (paper §4.1): periodic policy computation.
//!
//! Single-threaded, push-based loop: (1) aggregate telemetry from every
//! node store plus future-table state counts into a [`ClusterView`];
//! (2) run the installed policies; (3) apply their Table-2 commands —
//! routing updates into the shared router, priority updates onto future
//! metadata, migrations as bus commands to the source component
//! controller, kill/provision through the deployment hooks. The loop is
//! never on the request fast path; component controllers keep serving
//! between (and during) ticks.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::component::LocalOrder;
use crate::coordinator::policy::{Policy, PolicyApi, PolicyCmd};
use crate::coordinator::router::{LoadMap, Router};
use crate::coordinator::{IngressMetrics, InstanceMetrics};
use crate::futures::{FutureState, FutureTable};
use crate::ids::{InstanceId, NodeId};
use crate::ingress::routing::SharedRoute;
use crate::nodestore::{keys, StoreDirectory};
use crate::trace::Ring;
use crate::transport::{Bus, Message};

/// How many loop timings the controller retains (Fig-10 reporting reads
/// a recent window, not the full history — an always-on deployment at a
/// 100ms period would otherwise grow this vector ~35K entries/hour for
/// its whole life). Same overwrite-oldest [`Ring`] as the trace
/// flight recorder; evictions are counted, not silent.
pub const TIMINGS_CAP: usize = 512;

/// One instance's slice of the cluster view.
#[derive(Debug, Clone)]
pub struct InstanceView {
    pub id: InstanceId,
    pub node: NodeId,
    pub m: InstanceMetrics,
}

/// What policies see each tick.
#[derive(Debug, Clone, Default)]
pub struct ClusterView {
    pub instances: Vec<InstanceView>,
    /// Ingress front-door queues (one entry per workflow), when an
    /// [`crate::ingress::Ingress`] is serving this deployment.
    pub ingress: Vec<IngressMetrics>,
    pub future_counts: HashMap<FutureState, usize>,
    pub total_futures: usize,
    /// Telemetry collection time for this tick (Fig. 10 breakdown).
    pub collect_time: Duration,
}

impl ClusterView {
    pub fn instances_of<'a>(
        &'a self,
        agent: &'a str,
    ) -> impl Iterator<Item = &'a InstanceView> + 'a {
        self.instances.iter().filter(move |i| i.m.agent == agent)
    }

    pub fn agents(&self) -> Vec<String> {
        let mut v: Vec<String> = self.instances.iter().map(|i| i.m.agent.clone()).collect();
        v.sort();
        v.dedup();
        v
    }

    /// Mean (queued + active) load of an agent type.
    pub fn mean_load(&self, agent: &str) -> f64 {
        let xs: Vec<f64> = self
            .instances_of(agent)
            .map(|i| (i.m.queue_len + i.m.active) as f64)
            .collect();
        if xs.is_empty() {
            0.0
        } else {
            xs.iter().sum::<f64>() / xs.len() as f64
        }
    }
}

/// Timing of one control-loop iteration (Fig. 10's metric).
#[derive(Debug, Clone, Copy, Default)]
pub struct LoopTiming {
    pub collect: Duration,
    pub policy: Duration,
    pub apply: Duration,
}

impl LoopTiming {
    pub fn total(&self) -> Duration {
        self.collect + self.policy + self.apply
    }
}

/// Deployment hooks for `kill` / `provision` (instance lifecycle lives in
/// the deployment, not the controller).
pub type ProvisionFn = dyn Fn(&str) -> Option<InstanceId> + Send + Sync;

/// See module docs.
pub struct GlobalController {
    bus: Bus,
    stores: StoreDirectory,
    router: Arc<Router>,
    loads: LoadMap,
    table: Arc<FutureTable>,
    policies: Mutex<Vec<Box<dyn Policy>>>,
    provision: Arc<ProvisionFn>,
    timings: Mutex<Ring<LoopTiming>>,
    /// The deployment's JIT-routing slot (empty until an `Ingress` with
    /// variants configured installs a `RouteState`): `RouteControl`
    /// commands land here. A slot, not a direct reference, for the same
    /// reason component controllers hold one — the controller outlives
    /// and predates any particular ingress.
    route: Mutex<SharedRoute>,
}

impl GlobalController {
    pub fn new(
        bus: Bus,
        stores: StoreDirectory,
        router: Arc<Router>,
        loads: LoadMap,
        table: Arc<FutureTable>,
        policies: Vec<Box<dyn Policy>>,
        provision: Arc<ProvisionFn>,
    ) -> Arc<Self> {
        Arc::new(GlobalController {
            bus,
            stores,
            router,
            loads,
            table,
            policies: Mutex::new(policies),
            provision,
            timings: Mutex::new(Ring::new(TIMINGS_CAP)),
            route: Mutex::new(SharedRoute::default()),
        })
    }

    /// Point `RouteControl` commands at the deployment's routing slot
    /// (the server wires this right after construction; the slot stays
    /// empty — and the commands no-ops — until an ingress with model
    /// variants installs its `RouteState`).
    pub fn set_route_slot(&self, slot: SharedRoute) {
        *self.route.lock().unwrap() = slot;
    }

    /// Aggregate telemetry (the paper's "collecting state": Fig. 10 shows
    /// 76ms for 1K futures on 64 nodes up to 151ms at 130K).
    pub fn collect(&self) -> ClusterView {
        let t0 = Instant::now();
        let mut instances = Vec::new();
        for (node, store) in self.stores.nodes() {
            for (key, m) in store.scan::<InstanceMetrics>(keys::METRICS_PREFIX) {
                let name = key.trim_start_matches(keys::METRICS_PREFIX);
                if let Some((agent, idx)) = name.rsplit_once(':') {
                    if let Ok(index) = idx.parse::<u32>() {
                        let id = InstanceId::new(agent, index);
                        if self.bus.is_registered(&id) {
                            instances.push(InstanceView { id, node, m: (*m).clone() });
                        }
                    }
                }
            }
        }
        instances.sort_by(|a, b| {
            (a.id.agent.as_str(), a.id.index).cmp(&(b.id.agent.as_str(), b.id.index))
        });
        let mut ingress: Vec<IngressMetrics> = Vec::new();
        for (_node, store) in self.stores.nodes() {
            for (_key, m) in store.scan::<IngressMetrics>(keys::INGRESS_PREFIX) {
                ingress.push((*m).clone());
            }
        }
        ingress.sort_by(|a, b| a.workflow.cmp(&b.workflow));
        let future_counts = self.table.state_counts();
        let total_futures = future_counts.values().sum();
        ClusterView {
            instances,
            ingress,
            future_counts,
            total_futures,
            collect_time: t0.elapsed(),
        }
    }

    /// One periodic iteration: collect -> policies -> apply. Returns the
    /// timing breakdown (recorded for Fig. 10).
    pub fn tick(&self) -> LoopTiming {
        let view = self.collect();
        let collect = view.collect_time;

        let t1 = Instant::now();
        let mut api = PolicyApi::new();
        {
            let mut policies = self.policies.lock().unwrap();
            for p in policies.iter_mut() {
                p.tick(&view, &mut api);
            }
        }
        let policy = t1.elapsed();

        let t2 = Instant::now();
        self.apply(api.cmds);
        let apply = t2.elapsed();

        let timing = LoopTiming { collect, policy, apply };
        self.timings.lock().unwrap().push(timing);
        timing
    }

    /// Apply Table-2 commands (push-based installation).
    ///
    /// §Perf: `set_priority` commands are batched into ONE pass over the
    /// future table. Policies commonly emit one priority update per waiting
    /// session (SRTF/LPT do), and a scan per command made `apply` O(cmds ×
    /// futures) — 598ms at 131K futures/128 agents before batching, 30ms
    /// after (EXPERIMENTS.md §Perf).
    pub fn apply(&self, cmds: Vec<PolicyCmd>) {
        let mut priorities: HashMap<crate::ids::SessionId, Vec<(Option<String>, i32)>> =
            HashMap::new();
        for cmd in cmds {
            match cmd {
                PolicyCmd::RouteSession { session, agent, instance } => {
                    self.router.pin(session, &agent, instance);
                }
                PolicyCmd::RouteWeights { agent, weights } => {
                    self.router.set_weights(&agent, weights);
                }
                PolicyCmd::SetPriority { session, priority, agent } => {
                    priorities.entry(session).or_default().push((agent, priority));
                }
                PolicyCmd::Migrate { session, from, to } => {
                    // Fig. 8 step 1: the command; steps 2-6 happen between
                    // the component controllers.
                    self.bus.send(&from, Message::MigrateOut { session, to });
                }
                PolicyCmd::Kill(instance) => {
                    self.bus.send(&instance, Message::Shutdown);
                    self.loads.deregister(&instance);
                }
                PolicyCmd::Provision { agent } => {
                    (self.provision)(&agent);
                }
                PolicyCmd::InstallOrder { instance, order } => {
                    if let Some(node) = self.bus.node_of(&instance) {
                        self.stores.node(node).put(&keys::policy(&instance), order);
                    }
                }
                PolicyCmd::RouteControl { slack_fast_s, headroom_large, quality_floor } => {
                    if let Some(rs) = self.route.lock().unwrap().get() {
                        rs.set_thresholds(slack_fast_s, headroom_large, quality_floor);
                    }
                }
            }
        }
        if !priorities.is_empty() {
            self.table.for_each(|cell| {
                let matched = cell.with_meta(|m| {
                    priorities.get(&m.session).map(|rules| (m.agent.clone(), rules.clone()))
                });
                if let Some((agent, rules)) = matched {
                    for (filter, priority) in rules {
                        let applies = match &filter {
                            Some(a) => agent.as_str() == a.as_str(),
                            None => true,
                        };
                        if applies {
                            cell.set_priority(priority);
                        }
                    }
                }
            });
        }
    }

    /// Snapshot of the retained loop timings, oldest first (Fig-10
    /// reporting; at most [`TIMINGS_CAP`] entries — older ticks have been
    /// overwritten, see [`Self::timings_evicted`]).
    pub fn timings_snapshot(&self) -> Vec<LoopTiming> {
        self.timings.lock().unwrap().iter().copied().collect()
    }

    /// Timings evicted by the bounded ring (0 until the cap is reached).
    pub fn timings_evicted(&self) -> u64 {
        self.timings.lock().unwrap().dropped()
    }

    /// Run the periodic loop until `stop` (spawned by the deployment).
    pub fn run(self: Arc<Self>, period: Duration, stop: Arc<AtomicBool>) {
        while !stop.load(Ordering::Relaxed) {
            let t = self.tick();
            let sleep = period.saturating_sub(t.total());
            std::thread::sleep(sleep.max(Duration::from_millis(1)));
        }
    }

    /// Install a default local order everywhere (used at startup).
    pub fn install_order_everywhere(&self, order: LocalOrder) {
        for (id, node) in self.bus.all_instances() {
            self.stores.node(node).put(&keys::policy(&id), order);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::futures::{FutureCell, FutureMeta};
    use crate::ids::*;

    type Globals = (Arc<GlobalController>, Bus, StoreDirectory, Arc<FutureTable>);

    fn mk_global(policies: Vec<Box<dyn Policy>>) -> Globals {
        let bus = Bus::new(Duration::ZERO);
        let stores = StoreDirectory::new(&[NodeId(0), NodeId(1)]);
        let loads = LoadMap::new();
        let table = Arc::new(FutureTable::new());
        let router = Arc::new(Router::new(bus.clone(), loads.clone(), 7));
        let g = GlobalController::new(
            bus.clone(),
            stores.clone(),
            router,
            loads,
            table.clone(),
            policies,
            Arc::new(|_| None),
        );
        (g, bus, stores, table)
    }

    #[test]
    fn collect_reads_all_node_stores() {
        let (g, bus, stores, _t) = mk_global(vec![]);
        let a0 = InstanceId::new("a", 0);
        let b0 = InstanceId::new("b", 0);
        let _r1 = bus.register(a0.clone(), NodeId(0));
        let _r2 = bus.register(b0.clone(), NodeId(1));
        stores.node(NodeId(0)).put(
            &keys::instance_metrics(&a0),
            InstanceMetrics { agent: "a".into(), queue_len: 3, ..Default::default() },
        );
        stores.node(NodeId(1)).put(
            &keys::instance_metrics(&b0),
            InstanceMetrics { agent: "b".into(), queue_len: 5, ..Default::default() },
        );
        let view = g.collect();
        assert_eq!(view.instances.len(), 2);
        assert_eq!(view.mean_load("b"), 5.0);
        assert_eq!(view.agents(), vec!["a", "b"]);
    }

    #[test]
    fn collect_surfaces_ingress_telemetry() {
        let (g, _bus, stores, _t) = mk_global(vec![]);
        stores.node(NodeId(0)).put(
            &keys::ingress("router"),
            IngressMetrics {
                workflow: "router".into(),
                depth: 17,
                cap: 64,
                policy: "bounded".into(),
                accepted: 100,
                shed: 9,
                tenants: vec![crate::coordinator::TenantMetrics {
                    tenant: "batch".into(),
                    weight: 2.0,
                    depth: 17,
                    accepted: 100,
                    shed: 9,
                    ..Default::default()
                }],
                ..Default::default()
            },
        );
        let view = g.collect();
        assert_eq!(view.ingress.len(), 1);
        let ing = &view.ingress[0];
        assert_eq!(ing.workflow, "router");
        assert_eq!(ing.depth, 17);
        assert_eq!(ing.shed, 9, "shed counts must reach policies");
        // the per-tenant split rides the same snapshot: tenant-aware
        // policies (per-tenant SLOs, weighted provisioning) need no new
        // plumbing
        assert_eq!(ing.tenants.len(), 1);
        assert_eq!(ing.tenants[0].tenant, "batch");
        assert_eq!(ing.tenants[0].weight, 2.0);
        assert_eq!(ing.tenants[0].shed, 9, "per-tenant sheds must reach policies");
    }

    #[test]
    fn dead_instances_excluded_from_view() {
        let (g, bus, stores, _t) = mk_global(vec![]);
        let a0 = InstanceId::new("a", 0);
        let _rx = bus.register(a0.clone(), NodeId(0));
        stores.node(NodeId(0)).put(
            &keys::instance_metrics(&a0),
            InstanceMetrics { agent: "a".into(), ..Default::default() },
        );
        bus.deregister(&a0);
        assert_eq!(g.collect().instances.len(), 0, "stale telemetry must be dropped");
    }

    #[test]
    fn set_priority_applies_to_session_futures() {
        let (g, _bus, _stores, table) = mk_global(vec![]);
        for i in 0..4 {
            let meta = FutureMeta::new(
                FutureId(i),
                SessionId(i % 2),
                RequestId(0),
                AgentType::new("a"),
                "m",
                Location::Global,
            );
            table.insert(FutureCell::new(meta));
        }
        g.apply(vec![PolicyCmd::SetPriority { session: SessionId(1), priority: 9, agent: None }]);
        let mut boosted = 0;
        table.for_each(|c| {
            if c.priority() == 9 {
                boosted += 1;
                assert_eq!(c.session(), SessionId(1));
            }
        });
        assert_eq!(boosted, 2);
    }

    #[test]
    fn migrate_cmd_reaches_source_instance() {
        let (g, bus, _stores, _t) = mk_global(vec![]);
        let from = InstanceId::new("a", 0);
        let rx = bus.register(from.clone(), NodeId(0));
        g.apply(vec![PolicyCmd::Migrate {
            session: SessionId(5),
            from: from.clone(),
            to: InstanceId::new("a", 1),
        }]);
        match rx.try_recv().unwrap() {
            Message::MigrateOut { session, to } => {
                assert_eq!(session, SessionId(5));
                assert_eq!(to.index, 1);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn install_order_via_node_store_pubsub() {
        let (g, bus, stores, _t) = mk_global(vec![]);
        let a0 = InstanceId::new("a", 0);
        let _rx = bus.register(a0.clone(), NodeId(0));
        let sub = stores.node(NodeId(0)).subscribe(&keys::policy(&a0));
        g.apply(vec![PolicyCmd::InstallOrder { instance: a0, order: LocalOrder::Priority }]);
        let (_, v) = sub.rx.try_recv().unwrap();
        assert_eq!(*v.downcast::<LocalOrder>().unwrap(), LocalOrder::Priority);
    }

    #[test]
    fn provision_hook_called() {
        let bus = Bus::new(Duration::ZERO);
        let stores = StoreDirectory::new(&[NodeId(0)]);
        let loads = LoadMap::new();
        let table = Arc::new(FutureTable::new());
        let router = Arc::new(Router::new(bus.clone(), loads.clone(), 7));
        let called = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let c2 = called.clone();
        let g = GlobalController::new(
            bus,
            stores,
            router,
            loads,
            table,
            vec![],
            Arc::new(move |agent| {
                assert_eq!(agent, "dev");
                c2.fetch_add(1, Ordering::Relaxed);
                None
            }),
        );
        g.apply(vec![PolicyCmd::Provision { agent: "dev".into() }]);
        assert_eq!(called.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn route_control_lands_in_the_installed_slot() {
        use crate::config::ModelVariant;
        use crate::ingress::routing::{RouteMode, RouteState, SharedRoute};
        let (g, _bus, _stores, _t) = mk_global(vec![]);
        // empty slot: the command is a no-op, not a panic
        g.apply(vec![PolicyCmd::RouteControl {
            slack_fast_s: 1.0,
            headroom_large: 3.0,
            quality_floor: 0.5,
        }]);
        let slot = SharedRoute::default();
        g.set_route_slot(slot.clone());
        let variants = vec![
            ModelVariant { name: "fast".into(), latency_mult: 0.5, quality: 0.8 },
            ModelVariant { name: "large".into(), latency_mult: 2.0, quality: 0.99 },
        ];
        let rs = RouteState::new(RouteMode::Jit, &variants).unwrap();
        slot.install(rs.clone());
        g.apply(vec![PolicyCmd::RouteControl {
            slack_fast_s: 1.5,
            headroom_large: 6.0,
            quality_floor: 0.9,
        }]);
        assert_eq!(rs.thresholds(), (1.5, 6.0, 0.9), "thresholds pushed through the slot");
    }

    #[test]
    fn tick_records_timing() {
        let (g, _bus, _stores, _t) = mk_global(vec![]);
        let t = g.tick();
        assert!(t.total() < Duration::from_secs(1));
        assert_eq!(g.timings_snapshot().len(), 1);
        assert_eq!(g.timings_evicted(), 0);
    }

    #[test]
    fn timings_storage_is_bounded_at_capacity() {
        let (g, _bus, _stores, _t) = mk_global(vec![]);
        let extra = 5;
        for _ in 0..TIMINGS_CAP + extra {
            g.tick();
        }
        let snap = g.timings_snapshot();
        assert_eq!(snap.len(), TIMINGS_CAP, "ring must enforce its capacity");
        assert_eq!(g.timings_evicted(), extra as u64, "evictions are counted");
    }
}
