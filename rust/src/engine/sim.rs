//! Profiled-latency engine core for emulated-cluster benches.
//!
//! Models exactly the serving dynamics the Fig-9 experiments depend on:
//! prefill cost ∝ prompt tokens, decode cost per token with sub-linear
//! batch scaling, KV residency penalties (promotion transfer / full
//! recompute on miss), and lognormal output lengths. Times come from the
//! agent's [`LatencyProfile`] (paper seconds) scaled by the deployment
//! `time_scale`; `step` really sleeps, so queueing behaviour emerges from
//! the same code paths the PJRT core uses. The paper itself evaluates
//! scalability this way (§6.3: "profiles LLM inference calls to mimic
//! execution behavior").

use std::sync::Arc;
use std::time::Duration;

use crate::config::LatencyProfile;
use crate::engine::{EngineCore, EngineDone, EngineReq, GenOut};
use crate::ids::SessionId;
use crate::state::kvcache::{KvCacheManager, Residency};
use crate::util::rng::Rng;

struct ActiveSeq {
    tag: u64,
    session: SessionId,
    prompt_tokens: usize,
    target_tokens: usize,
    generated: usize,
    /// Pending non-decode work (prefill / KV transfer), in wall-clock time,
    /// consumed before decoding starts.
    pending_work: Duration,
    kv_outcome: &'static str,
    /// Routed variant's service-time multiplier (1.0 unrouted). Decode
    /// throughput scales inversely: a 0.35x variant emits ~3 tokens per
    /// batch step, a 2.2x variant ~0.45 — so variants of different sizes
    /// coexist in one continuous batch.
    mult: f64,
    /// Fractional decode progress toward the next token (see `mult`).
    progress: f64,
}

/// See module docs.
pub struct SimCore {
    profile: LatencyProfile,
    time_scale: f64,
    max_batch: usize,
    kv: Arc<KvCacheManager>,
    rng: Rng,
    active: Vec<ActiveSeq>,
    /// Approx bytes of KV per history token (cost model; matches the real
    /// model's 2*L*H*Dh*4 per token).
    kv_bytes_per_token: u64,
}

impl SimCore {
    pub fn new(
        profile: LatencyProfile,
        time_scale: f64,
        max_batch: usize,
        kv: Arc<KvCacheManager>,
        seed: u64,
    ) -> Self {
        SimCore {
            profile,
            time_scale,
            max_batch,
            kv,
            rng: Rng::new(seed),
            active: Vec::new(),
            kv_bytes_per_token: 2 * 2 * 4 * 16 * 4, // L=2,H=4,Dh=16,f32
        }
    }

    fn scaled(&self, paper_s: f64) -> Duration {
        Duration::from_secs_f64((paper_s * self.time_scale).max(0.0))
    }

    /// Decode-step wall time for a batch of size `b` (sub-linear scaling).
    fn step_time(&self, b: usize) -> Duration {
        let factor = 1.0 + self.profile.batch_slope * (b.saturating_sub(1)) as f64;
        self.scaled(self.profile.per_output_token_s * factor)
    }
}

impl EngineCore for SimCore {
    fn admit(&mut self, req: EngineReq) {
        let prompt_tokens = req.prompt.len() / 4 + 8; // ~chars/4 heuristic
        let total_context = prompt_tokens + req.history_tokens;
        let kv_bytes = (total_context as u64) * self.kv_bytes_per_token;

        // KV residency decides how much context must be (re)computed.
        let residency = self.kv.ensure_resident(req.session, kv_bytes, total_context as u32);
        let (kv_outcome, prefill_tokens, transfer) = match residency {
            Residency::Hit => ("hit", prompt_tokens, Duration::ZERO),
            Residency::Promoted { transfer_us, .. } => {
                ("promoted", prompt_tokens, Duration::from_micros(transfer_us))
            }
            // miss: recompute the entire context
            Residency::Miss => ("miss", total_context, Duration::ZERO),
        };

        let cap = (4.0 * self.profile.mean_output_tokens).max(1.0);
        let target = self
            .rng
            .lognormal_mean(self.profile.mean_output_tokens, self.profile.output_sigma)
            .clamp(1.0, cap) as usize;
        // The routed variant scales prefill cost and decode throughput
        // (JIT routing, DESIGN.md §13); 1.0 = the profile as written.
        let mult = if req.latency_mult.is_finite() && req.latency_mult > 0.0 {
            req.latency_mult
        } else {
            1.0
        };
        let pending = self
            .scaled(
                (self.profile.base_s + self.profile.per_prompt_token_s * prefill_tokens as f64)
                    * mult,
            )
            + transfer;

        self.active.push(ActiveSeq {
            tag: req.tag,
            session: req.session,
            prompt_tokens,
            target_tokens: target.min(req.max_new_tokens.max(1)),
            generated: 0,
            pending_work: pending,
            kv_outcome,
            mult,
            progress: 0.0,
        });
    }

    fn step(&mut self) -> Vec<EngineDone> {
        if self.active.is_empty() {
            return Vec::new();
        }
        let b = self.active.len().min(self.max_batch);
        let dt = self.step_time(b);

        // Pay the largest pending (prefill/transfer) work in this step
        // window plus one decode step. Sequences still in prefill don't
        // decode this step.
        let max_pending = self
            .active
            .iter()
            .take(b)
            .map(|s| s.pending_work)
            .max()
            .unwrap_or(Duration::ZERO);
        let wall = dt + max_pending.min(self.step_time(1) * 4); // prefill overlaps decode partially
        std::thread::sleep(wall);

        let mut done = Vec::new();
        let mut i = 0;
        let mut processed = 0;
        while i < self.active.len() {
            if processed >= b {
                break;
            }
            processed += 1;
            let seq = &mut self.active[i];
            if seq.pending_work > Duration::ZERO {
                seq.pending_work = seq.pending_work.saturating_sub(wall);
                i += 1;
                continue;
            }
            // one batch step advances this sequence by 1/mult tokens:
            // fast variants emit several, large variants less than one
            seq.progress += 1.0 / seq.mult;
            while seq.progress >= 1.0 && seq.generated < seq.target_tokens {
                seq.progress -= 1.0;
                seq.generated += 1;
            }
            if seq.generated >= seq.target_tokens {
                let seq = self.active.remove(i);
                done.push(EngineDone {
                    tag: seq.tag,
                    session: seq.session,
                    result: Ok(GenOut {
                        text: format!("<sim:{} tokens>", seq.generated),
                        prompt_tokens: seq.prompt_tokens,
                        generated_tokens: seq.generated,
                        kv_outcome: seq.kv_outcome,
                    }),
                });
            } else {
                i += 1;
            }
        }
        done
    }

    fn active(&self) -> usize {
        self.active.len()
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn kv_manager(&self) -> &Arc<KvCacheManager> {
        &self.kv
    }

    fn evict_session(&mut self, session: SessionId) {
        self.kv.drop_session(session);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::state::kvcache::KvPolicy;

    fn core(max_batch: usize) -> SimCore {
        let profile = LatencyProfile {
            base_s: 0.0,
            per_prompt_token_s: 0.0001,
            per_output_token_s: 0.001,
            mean_output_tokens: 5.0,
            output_sigma: 0.1,
            batch_slope: 0.2,
        };
        let kv = Arc::new(KvCacheManager::new(64 << 20, 256 << 20, KvPolicy::HintDriven));
        SimCore::new(profile, 1.0, max_batch, kv, 7)
    }

    fn req(tag: u64, session: u64) -> EngineReq {
        EngineReq {
            tag,
            session: SessionId(session),
            prompt: "analyze".into(),
            history_tokens: 0,
            max_new_tokens: 64,
            variant: None,
            latency_mult: 1.0,
        }
    }

    #[test]
    fn completes_all_requests() {
        let mut c = core(4);
        for t in 0..3 {
            c.admit(req(t, t));
        }
        let mut done = Vec::new();
        let mut guard = 0;
        while c.active() > 0 {
            done.extend(c.step());
            guard += 1;
            assert!(guard < 200, "engine made no progress");
        }
        assert_eq!(done.len(), 3);
        let tags: Vec<u64> = done.iter().map(|d| d.tag).collect();
        assert!(tags.contains(&0) && tags.contains(&1) && tags.contains(&2));
        for d in &done {
            let out = d.result.as_ref().unwrap();
            assert!(out.generated_tokens >= 1);
            assert_eq!(out.kv_outcome, "miss"); // fresh sessions
        }
    }

    #[test]
    fn batching_is_sublinear() {
        // 4 requests batched must finish in well under 4x the single time.
        let t1 = {
            let mut c = core(1);
            c.admit(req(0, 0));
            let start = std::time::Instant::now();
            while c.active() > 0 {
                c.step();
            }
            start.elapsed()
        };
        let t4 = {
            let mut c = core(4);
            for t in 0..4 {
                c.admit(req(t, t));
            }
            let start = std::time::Instant::now();
            while c.active() > 0 {
                c.step();
            }
            start.elapsed()
        };
        assert!(
            t4 < t1 * 3,
            "batched 4 took {t4:?} vs single {t1:?} — no batching benefit"
        );
    }

    #[test]
    fn session_reuse_hits_kv() {
        let mut c = core(2);
        c.admit(req(0, 42));
        while c.active() > 0 {
            c.step();
        }
        // same session returns: context is resident
        c.admit(EngineReq { history_tokens: 30, ..req(1, 42) });
        let mut outcome = "";
        while c.active() > 0 {
            for d in c.step() {
                outcome = d.result.unwrap().kv_outcome;
            }
        }
        assert_eq!(outcome, "hit");
    }

    #[test]
    fn variant_latency_mult_scales_decode_throughput() {
        // Steps-to-completion must shrink with a fast variant and grow
        // with a large one; token counts stay the profile's (the variant
        // changes speed, not output length — same seed, same target).
        let steps_for = |mult: f64| {
            let mut c = core(1);
            c.admit(EngineReq { latency_mult: mult, ..req(0, 0) });
            let mut steps = 0;
            let mut tokens = 0;
            while c.active() > 0 {
                for d in c.step() {
                    tokens = d.result.unwrap().generated_tokens;
                }
                steps += 1;
                assert!(steps < 500, "no progress at mult {mult}");
            }
            (steps, tokens)
        };
        let (s_fast, t_fast) = steps_for(0.25);
        let (s_base, t_base) = steps_for(1.0);
        let (s_large, t_large) = steps_for(4.0);
        assert_eq!(t_fast, t_base, "variant must not change output length");
        assert_eq!(t_large, t_base, "variant must not change output length");
        assert!(s_fast < s_base, "fast {s_fast} !< base {s_base}");
        assert!(s_large > s_base, "large {s_large} !> base {s_base}");
    }

    #[test]
    fn step_time_grows_sublinearly() {
        let c = core(8);
        let t1 = c.step_time(1);
        let t8 = c.step_time(8);
        assert!(t8 > t1);
        assert!(t8 < t1 * 8);
    }
}
