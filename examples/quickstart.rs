//! Quickstart: the full three-layer stack on a real workload.
//!
//! Loads the AOT artifacts (JAX transformer + Pallas attention, compiled
//! to HLO by `make artifacts`), launches a 2-node NALAR deployment whose
//! LLM agents execute through PJRT, and serves a batch of real requests
//! through the financial-analyst workflow — Python nowhere on the path.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use std::time::{Duration, Instant};

use nalar::baselines::SystemUnderTest;
use nalar::config::DeploymentConfig;
use nalar::json;
use nalar::server::Deployment;
use nalar::util::rng::Rng;
use nalar::workflow::{self, Env};
use nalar::workload;

const CONFIG: &str = r#"{
  "nodes": 2,
  "time_scale": 1.0,
  "seed": 1,
  "control": {"global_period_ms": 50},
  "engine": {"max_batch": 4, "executor": "pjrt", "artifacts_dir": "artifacts", "kv_policy": "hint"},
  "agents": [
    {"name": "stock_analysis", "kind": "llm", "instances": 1,
     "directives": {"batchable": true, "max_instances": 2}, "methods": ["analyze"],
     "profile": {"base_s": 0.0}},
    {"name": "bond_market", "kind": "llm", "instances": 1,
     "directives": {"batchable": true, "max_instances": 2}, "methods": ["analyze"],
     "profile": {"base_s": 0.0}},
    {"name": "market_research", "kind": "llm", "instances": 1,
     "directives": {"batchable": true, "max_instances": 2}, "methods": ["analyze"],
     "profile": {"base_s": 0.0}},
    {"name": "web_search", "kind": "web_search", "instances": 1,
     "directives": {"max_instances": 2}, "methods": ["search"],
     "profile": {"base_s": 0.01}},
    {"name": "analyst", "kind": "llm", "instances": 2,
     "directives": {"managed_state": true, "max_instances": 4}, "methods": ["summarize"],
     "profile": {"base_s": 0.0}}
  ],
  "policies": ["load_balance", "hol_migration"]
}"#;

fn main() -> nalar::Result<()> {
    println!("== NALAR quickstart: PJRT-backed financial-analyst workflow ==");
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        return Err(nalar::Error::msg("artifacts missing — run `make artifacts` first"));
    }

    let cfg = DeploymentConfig::from_json(CONFIG)?;
    let t_launch = Instant::now();
    let d = Deployment::launch_as(cfg, SystemUnderTest::Nalar)?;
    println!(
        "deployment up in {:.2?} (HLO compiled, weights uploaded)",
        t_launch.elapsed()
    );

    let mut rng = Rng::new(42);
    let n_sessions = 4;
    let turns = 2;
    let timeout = Duration::from_secs(120);

    let mut latencies = Vec::new();
    let t0 = Instant::now();
    for s in 0..n_sessions {
        let session = d.new_session();
        for turn in 0..turns {
            let q = if turn == 0 {
                workload::finqa_question(&mut rng)
            } else {
                workload::finqa_followup(&mut rng)
            };
            let env = Env::new(&d, session);
            let t = Instant::now();
            let out = workflow::financial::run(
                &env,
                &json!({"question": q.as_str(), "max_new": 20}),
                timeout,
            )?;
            let dt = t.elapsed();
            latencies.push(dt);
            println!(
                "  session {s} turn {turn}: {:>8.2?}  kv={:<8}  q=\"{}\"",
                dt,
                out.get("kv").as_str().unwrap_or("?"),
                &q[..q.len().min(48)]
            );
        }
    }
    let wall = t0.elapsed();

    // Phase 2: session continuation on one agent — short turns fit the
    // 128-token context, so the engine reuses the session KV cache
    // (incremental decode) instead of re-prefilling: kv=hit.
    println!("\n== session KV reuse (multi-turn chat on `analyst`) ==");
    let session = d.new_session();
    for (turn, q) in ["rates?", "why?", "and now?"].iter().enumerate() {
        let env = Env::new(&d, session);
        let f = env.ctx.agent("analyst").call(
            "summarize",
            json!({"prompt": *q, "max_new_tokens": 12}),
        );
        let out = f.value(timeout)?;
        println!(
            "  turn {turn}: kv={:<8} ({} prompt + {} generated tokens)",
            out.get("kv").as_str().unwrap_or("?"),
            out.get("prompt_tokens").as_i64().unwrap_or(0),
            out.get("generated_tokens").as_i64().unwrap_or(0),
        );
    }

    latencies.sort();
    let p = |q: f64| latencies[((latencies.len() - 1) as f64 * q) as usize];
    println!("\n== results ==");
    println!("requests      : {}", latencies.len());
    println!(
        "throughput    : {:.2} req/s",
        latencies.len() as f64 / wall.as_secs_f64()
    );
    println!("latency p50   : {:.2?}", p(0.5));
    println!("latency p95   : {:.2?}", p(0.95));
    println!("latency max   : {:.2?}", latencies.last().unwrap());
    println!("bus messages  : {}", d.bus().messages_sent());
    println!("live futures  : {}", d.table().len());

    let view = d.global().collect();
    for i in &view.instances {
        println!(
            "  {:<18} node {}  completed {:>3}  failed {}",
            i.id.to_string(),
            i.node,
            i.m.completed,
            i.m.failed
        );
    }
    d.shutdown();
    println!("OK");
    Ok(())
}
