//! Router-based workflow (paper §6, Fig. 9b).
//!
//! A lightweight router agent classifies each query, then the request
//! branches: chat queries go to the chat agent; coding queries go to a
//! coding agent whose output is checked by the test harness. Branch
//! popularity shifts over the trace (Azure-like, >90% imbalance), which is
//! what NALAR's resource reallocation exploits and static baselines
//! cannot (§6.1: AutoGen/Ayo fail at 70-80 RPS).

use std::time::Duration;

use crate::error::Result;
use crate::futures::Value;
use crate::json;
use crate::workflow::Env;

/// One request: classify, then branch.
pub fn run(env: &Env, input: &Value, timeout: Duration) -> Result<Value> {
    let prompt = input.get("prompt").as_str().unwrap_or("hello");
    // Ground-truth class rides along from the trace; the router agent's
    // (tiny) LLM call still happens — it is the classification cost.
    let class = input.get("class").as_str().unwrap_or("chat");

    let classify = env.ctx.agent("router").call(
        "classify",
        json!({"prompt": prompt, "max_new_tokens": 4}),
    );
    let _ = classify.value(timeout)?; // classification latency is on the path

    let deeper = env.ctx.deeper();
    if class == "coder" {
        let code = deeper.agent("coder").call(
            "implement",
            json!({"prompt": prompt, "max_new_tokens": 192}),
        );
        let code_out = code.value(timeout)?;
        let test = deeper.agent("test_harness").call_with(
            "unit_test",
            json!({"code": code_out.get("text").as_str().unwrap_or(""), "attempt": 0}),
            &[code.id()],
            0,
        );
        let test_out = test.value(timeout)?;
        Ok(json!({
            "branch": "coder",
            "test": test_out.get("result").as_str().unwrap_or("?"),
        }))
    } else {
        let reply = deeper.agent("chat").call(
            "reply",
            json!({"prompt": prompt, "max_new_tokens": 96}),
        );
        let out = reply.value(timeout)?;
        Ok(json!({
            "branch": "chat",
            "tokens": out.get("generated_tokens").as_i64().unwrap_or(0),
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::Deployment;
    use crate::workflow::WorkflowKind;

    #[test]
    fn both_branches_work() {
        let mut cfg = WorkflowKind::Router.config();
        cfg.time_scale = 0.0005;
        let d = Deployment::launch(cfg).unwrap();
        let timeout = Duration::from_secs(20);

        let env = Env::new(&d, d.new_session());
        let chat = run(&env, &json!({"prompt": "hi", "class": "chat"}), timeout).unwrap();
        assert_eq!(chat.get("branch").as_str(), Some("chat"));

        let env2 = Env::new(&d, d.new_session());
        let code = run(&env2, &json!({"prompt": "fix bug", "class": "coder"}), timeout).unwrap();
        assert_eq!(code.get("branch").as_str(), Some("coder"));
        let t = code.get("test").as_str().unwrap();
        assert!(t == "Pass" || t == "Fail");
        d.shutdown();
    }
}
