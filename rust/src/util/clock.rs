//! Injectable time source (a test-clock crate substitute, offline build).
//!
//! The ingress scheduler reads time through [`Clock`] instead of calling
//! `Instant::now()` directly, so deterministic tests can freeze and
//! `advance()` it ([`crate::testkit`] re-exports these for test code).
//! Production constructs [`Clock::wall`]; nothing here is test-only —
//! the scheduler genuinely runs against whichever source it is given.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A manually-advanced time source. `now()` is a real `Instant` (base
/// captured at construction + the advanced offset), so virtual timestamps
/// compare and subtract exactly like wall-clock ones — code under test
/// needs no special arithmetic, only a [`Clock`] instead of
/// `Instant::now()`.
pub struct VirtualClock {
    base: Instant,
    offset: Mutex<Duration>,
}

impl VirtualClock {
    fn new() -> Arc<VirtualClock> {
        Arc::new(VirtualClock { base: Instant::now(), offset: Mutex::new(Duration::ZERO) })
    }

    pub fn now(&self) -> Instant {
        self.base + *self.offset.lock().unwrap()
    }

    /// Move virtual time forward (it never goes back).
    pub fn advance(&self, d: Duration) {
        *self.offset.lock().unwrap() += d;
    }
}

/// The time source the ingress scheduler reads. Defaults to wall clock;
/// tests swap in a [`VirtualClock`] via [`Clock::manual`].
#[derive(Clone, Default)]
pub struct Clock(Option<Arc<VirtualClock>>);

impl Clock {
    /// Real time (the production default).
    pub fn wall() -> Clock {
        Clock(None)
    }

    /// A frozen clock plus the handle that advances it.
    pub fn manual() -> (Clock, Arc<VirtualClock>) {
        let v = VirtualClock::new();
        (Clock(Some(v.clone())), v)
    }

    pub fn now(&self) -> Instant {
        match &self.0 {
            None => Instant::now(),
            Some(v) => v.now(),
        }
    }

    /// Monotonic nanoseconds since `epoch` on this clock's time axis
    /// (saturating at zero for pre-epoch instants). The ingress publish
    /// and sweep throttles store these in atomics and advance them by
    /// compare-and-swap — lock-free, and still driven by `advance()` on
    /// a virtual clock exactly like deadlines are.
    pub fn nanos_since(&self, epoch: Instant) -> u64 {
        self.now().saturating_duration_since(epoch).as_nanos() as u64
    }
}

impl std::fmt::Debug for Clock {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(if self.0.is_some() { "Clock(virtual)" } else { "Clock(wall)" })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_is_frozen_until_advanced() {
        let (clock, v) = Clock::manual();
        let t0 = clock.now();
        std::thread::sleep(Duration::from_millis(2));
        assert_eq!(clock.now(), t0, "wall time must not leak into a virtual clock");
        v.advance(Duration::from_secs(3600));
        assert_eq!(clock.now() - t0, Duration::from_secs(3600));
        assert!(clock.now() > t0);
    }

    #[test]
    fn wall_clock_moves_on_its_own() {
        let clock = Clock::wall();
        let t0 = clock.now();
        std::thread::sleep(Duration::from_millis(2));
        assert!(clock.now() > t0);
    }

    #[test]
    fn nanos_since_follows_the_virtual_axis_and_saturates() {
        let (clock, v) = Clock::manual();
        let epoch = clock.now();
        assert_eq!(clock.nanos_since(epoch), 0);
        v.advance(Duration::from_millis(25));
        assert_eq!(clock.nanos_since(epoch), 25_000_000);
        // a pre-epoch reference saturates instead of wrapping
        assert_eq!(clock.nanos_since(epoch + Duration::from_secs(1)), 0);
    }
}
