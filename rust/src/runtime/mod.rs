//! PJRT runtime: load and execute the AOT artifacts from Rust.
//!
//! The compile path (`python/compile/aot.py`, run once by `make artifacts`)
//! lowers the L2 JAX model — with its L1 Pallas attention kernels — to HLO
//! *text* plus a raw weights blob. This module is the serving-side half:
//!
//! * [`manifest`] parses `artifacts/manifest.json` (entry signatures,
//!   weight layout, model dims);
//! * [`pjrt`] owns a dedicated executor thread that builds the
//!   `PjRtClient`, uploads the weights once, compiles every HLO entry, and
//!   serves prefill/decode/embed calls over a channel (a real XLA binding's
//!   handles hold raw pointers and are not `Send`, so all PJRT state lives
//!   on that one thread — matching "one GPU, one engine" anyway);
//! * [`xla`] is the offline stub for that binding: it mirrors the consumed
//!   API and fails fast at client construction (DESIGN.md §PJRT), so the
//!   `sim` executor carries every benchmark until a binding is vendored;
//! * [`kv`] packs/unpacks per-sequence KV caches in and out of the batched
//!   `[L, 2, B, H, S, Dh]` tensors the HLO expects — the Rust engine owns
//!   cache placement (paper §4.3.2).

pub mod kv;
pub mod manifest;
pub mod pjrt;
pub mod xla;

pub use kv::{KvBatch, SeqKv};
pub use manifest::{EntrySig, Manifest, ModelDims};
pub use pjrt::{DecodeOut, PjrtModel, PrefillOut};
