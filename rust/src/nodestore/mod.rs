//! Node store: the low-latency metadata + telemetry substrate (paper §4.1).
//!
//! The paper's prototype uses one Redis per node as a "telemetry-and-
//! decision broker": component-level controllers push metrics and local
//! observations *up*, the global controller writes policy updates *down*,
//! and neither side synchronizes with the other directly. This module is
//! that substrate built from scratch (substitution table, DESIGN.md §3):
//!
//! * sharded in-memory keyspace with per-key versions (optimistic reads),
//! * prefix scans (the global controller's aggregation primitive),
//! * prefix pub/sub so component controllers consume policy changes
//!   asynchronously — the global controller is never on the critical path.
//!
//! Values are `Arc<dyn Any + Send + Sync>`: control-plane structs move
//! through the store without serialization (the §Perf pass measured JSON
//! serialization dominating the Fig-10 loop; typed values removed it).

mod store;

pub use store::{NodeStore, StoreValue, Subscription};

use std::collections::HashMap;
use std::sync::Arc;

use crate::ids::NodeId;

/// One store per emulated node, plus a directory for cross-node access.
///
/// In the paper each node's controllers talk only to the local store while
/// the global controller reads all of them; `StoreDirectory` gives it that
/// reach.
#[derive(Clone)]
pub struct StoreDirectory {
    stores: Arc<HashMap<NodeId, Arc<NodeStore>>>,
}

impl StoreDirectory {
    pub fn new(nodes: &[NodeId]) -> Self {
        let stores = nodes
            .iter()
            .map(|&n| (n, Arc::new(NodeStore::new())))
            .collect();
        StoreDirectory { stores: Arc::new(stores) }
    }

    pub fn node(&self, node: NodeId) -> Arc<NodeStore> {
        self.stores
            .get(&node)
            .cloned()
            .unwrap_or_else(|| panic!("no store for node {node}"))
    }

    pub fn nodes(&self) -> impl Iterator<Item = (NodeId, &Arc<NodeStore>)> {
        self.stores.iter().map(|(k, v)| (*k, v))
    }

    pub fn len(&self) -> usize {
        self.stores.len()
    }

    pub fn is_empty(&self) -> bool {
        self.stores.is_empty()
    }
}

/// Canonical key layout used by the controllers.
pub mod keys {
    use crate::ids::{FutureId, InstanceId, SessionId};

    pub fn instance_metrics(i: &InstanceId) -> String {
        format!("metrics/{i}")
    }
    pub const METRICS_PREFIX: &str = "metrics/";

    pub fn policy(i: &InstanceId) -> String {
        format!("policy/{i}")
    }
    pub const POLICY_PREFIX: &str = "policy/";

    pub fn future_meta(f: FutureId) -> String {
        format!("future/{f}")
    }
    pub const FUTURE_PREFIX: &str = "future/";

    pub fn session_state(s: SessionId, key: &str) -> String {
        format!("state/{s}/{key}")
    }
    pub fn session_prefix(s: SessionId) -> String {
        format!("state/{s}/")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn directory_per_node_isolated() {
        let dir = StoreDirectory::new(&[NodeId(0), NodeId(1)]);
        dir.node(NodeId(0)).put("k", 1u64);
        assert_eq!(dir.node(NodeId(0)).get::<u64>("k"), Some(Arc::new(1u64)));
        assert!(dir.node(NodeId(1)).get::<u64>("k").is_none());
    }

    #[test]
    #[should_panic]
    fn missing_node_panics() {
        let dir = StoreDirectory::new(&[NodeId(0)]);
        dir.node(NodeId(9));
    }
}
