//! In-process message bus — the gRPC substitute (DESIGN.md §3).
//!
//! The paper's prototype wires every inter-component interaction over gRPC.
//! Here components live in one emulated-cluster process, so the bus gives
//! each component controller an inbox (std mpsc) and models the network:
//! cross-node sends incur an injectable one-way latency (delivered by a
//! dedicated timer thread so ordering per edge is preserved), and per-edge
//! counters feed the benches. Semantics match what the controllers assume
//! of gRPC: reliable, ordered per sender-receiver pair, asynchronous.

mod delay;
mod messages;

pub use messages::{CallMsg, Message, MigratePayload};

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, RwLock};
use std::time::Duration;

use crate::ids::{InstanceId, NodeId};
use delay::DelayLine;

struct Endpoint {
    node: NodeId,
    tx: mpsc::Sender<Message>,
}

/// Cluster-wide message bus. Cheap to clone.
#[derive(Clone)]
pub struct Bus {
    inner: Arc<BusInner>,
}

struct BusInner {
    endpoints: RwLock<HashMap<InstanceId, Endpoint>>,
    /// §Perf: per-agent-type instance index, maintained on register/
    /// deregister so the routing hot path avoids a scan+sort per call
    /// (12µs -> 0.3µs per route; EXPERIMENTS.md §Perf).
    by_agent: RwLock<HashMap<String, Vec<InstanceId>>>,
    /// One-way latency applied to cross-node sends (zero = ideal network).
    cross_node_latency: Duration,
    delay: DelayLine,
    sent: AtomicU64,
    cross_node_sent: AtomicU64,
}

impl Bus {
    pub fn new(cross_node_latency: Duration) -> Self {
        Bus {
            inner: Arc::new(BusInner {
                endpoints: RwLock::new(HashMap::new()),
                by_agent: RwLock::new(HashMap::new()),
                cross_node_latency,
                delay: DelayLine::new(),
                sent: AtomicU64::new(0),
                cross_node_sent: AtomicU64::new(0),
            }),
        }
    }

    /// Register an instance's inbox (at instance launch / `provision`).
    pub fn register(&self, instance: InstanceId, node: NodeId) -> mpsc::Receiver<Message> {
        let (tx, rx) = mpsc::channel();
        self.inner
            .endpoints
            .write()
            .unwrap()
            .insert(instance.clone(), Endpoint { node, tx });
        let mut idx = self.inner.by_agent.write().unwrap();
        let v = idx.entry(instance.agent.as_str().to_string()).or_default();
        if !v.contains(&instance) {
            v.push(instance);
            v.sort_by_key(|i| i.index);
        }
        rx
    }

    /// Remove an instance (the `kill` primitive). Pending messages in its
    /// inbox are dropped with the receiver, like connections to a dead pod.
    pub fn deregister(&self, instance: &InstanceId) {
        self.inner.endpoints.write().unwrap().remove(instance);
        if let Some(v) = self
            .inner
            .by_agent
            .write()
            .unwrap()
            .get_mut(instance.agent.as_str())
        {
            v.retain(|i| i != instance);
        }
    }

    pub fn is_registered(&self, instance: &InstanceId) -> bool {
        self.inner.endpoints.read().unwrap().contains_key(instance)
    }

    pub fn node_of(&self, instance: &InstanceId) -> Option<NodeId> {
        self.inner
            .endpoints
            .read()
            .unwrap()
            .get(instance)
            .map(|e| e.node)
    }

    /// Instances of one agent type currently registered (for routing).
    /// Served from the maintained index — this is on the stub hot path.
    pub fn instances_of(&self, agent: &str) -> Vec<InstanceId> {
        self.inner
            .by_agent
            .read()
            .unwrap()
            .get(agent)
            .cloned()
            .unwrap_or_default()
    }

    /// Visit instances of one agent type without allocating.
    pub fn with_instances_of<R>(&self, agent: &str, f: impl FnOnce(&[InstanceId]) -> R) -> R {
        static EMPTY: &[InstanceId] = &[];
        let idx = self.inner.by_agent.read().unwrap();
        f(idx.get(agent).map(|v| v.as_slice()).unwrap_or(EMPTY))
    }

    pub fn all_instances(&self) -> Vec<(InstanceId, NodeId)> {
        let mut v: Vec<(InstanceId, NodeId)> = self
            .inner
            .endpoints
            .read()
            .unwrap()
            .iter()
            .map(|(i, e)| (i.clone(), e.node))
            .collect();
        v.sort_by(|a, b| (a.0.agent.as_str(), a.0.index).cmp(&(b.0.agent.as_str(), b.0.index)));
        v
    }

    /// Send `msg` to `to`, applying cross-node latency when `from_node`
    /// differs from the target's node. Returns false if the target is gone
    /// (callers treat that as an instance failure, paper §5).
    pub fn send_from(&self, from_node: Option<NodeId>, to: &InstanceId, msg: Message) -> bool {
        let (tx, to_node) = {
            let eps = self.inner.endpoints.read().unwrap();
            match eps.get(to) {
                Some(e) => (e.tx.clone(), e.node),
                None => return false,
            }
        };
        self.inner.sent.fetch_add(1, Ordering::Relaxed);
        let cross = from_node.map(|f| f != to_node).unwrap_or(false);
        if cross {
            self.inner.cross_node_sent.fetch_add(1, Ordering::Relaxed);
        }
        let delay = if cross { self.inner.cross_node_latency } else { Duration::ZERO };
        if delay.is_zero() {
            tx.send(msg).is_ok()
        } else {
            self.inner.delay.deliver_after(delay, tx, msg);
            true
        }
    }

    /// Send without a source node (driver/global; treated as local).
    pub fn send(&self, to: &InstanceId, msg: Message) -> bool {
        self.send_from(None, to, msg)
    }

    pub fn messages_sent(&self) -> u64 {
        self.inner.sent.load(Ordering::Relaxed)
    }

    pub fn cross_node_messages(&self) -> u64 {
        self.inner.cross_node_sent.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::futures::{FutureCell, FutureMeta};
    use crate::ids::*;

    fn call(id: u64) -> Message {
        Message::Call(CallMsg {
            cell: FutureCell::new(FutureMeta::new(
                FutureId(id),
                SessionId(0),
                RequestId(0),
                AgentType::new("a"),
                "m",
                Location::Global,
            )),
            args: crate::json!({}),
        })
    }

    #[test]
    fn register_send_receive() {
        let bus = Bus::new(Duration::ZERO);
        let a = InstanceId::new("a", 0);
        let rx = bus.register(a.clone(), NodeId(0));
        assert!(bus.send(&a, call(1)));
        match rx.recv().unwrap() {
            Message::Call(c) => assert_eq!(c.cell.id, FutureId(1)),
            _ => panic!(),
        }
        assert_eq!(bus.messages_sent(), 1);
    }

    #[test]
    fn send_to_dead_instance_fails() {
        let bus = Bus::new(Duration::ZERO);
        let a = InstanceId::new("a", 0);
        let _rx = bus.register(a.clone(), NodeId(0));
        bus.deregister(&a);
        assert!(!bus.send(&a, call(1)));
        assert!(!bus.is_registered(&a));
    }

    #[test]
    fn cross_node_latency_applies() {
        let bus = Bus::new(Duration::from_millis(30));
        let a = InstanceId::new("a", 0);
        let rx = bus.register(a.clone(), NodeId(1));
        let t0 = std::time::Instant::now();
        assert!(bus.send_from(Some(NodeId(0)), &a, call(1)));
        let _ = rx.recv().unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(25));
        assert_eq!(bus.cross_node_messages(), 1);

        // same-node is immediate
        let t1 = std::time::Instant::now();
        assert!(bus.send_from(Some(NodeId(1)), &a, call(2)));
        let _ = rx.recv().unwrap();
        assert!(t1.elapsed() < Duration::from_millis(10));
    }

    #[test]
    fn delayed_sends_preserve_order_per_edge() {
        let bus = Bus::new(Duration::from_millis(5));
        let a = InstanceId::new("a", 0);
        let rx = bus.register(a.clone(), NodeId(1));
        for i in 0..20 {
            bus.send_from(Some(NodeId(0)), &a, call(i));
        }
        for i in 0..20 {
            match rx.recv().unwrap() {
                Message::Call(c) => assert_eq!(c.cell.id, FutureId(i)),
                _ => panic!(),
            }
        }
    }

    #[test]
    fn instances_of_sorted() {
        let bus = Bus::new(Duration::ZERO);
        let _r1 = bus.register(InstanceId::new("dev", 1), NodeId(0));
        let _r0 = bus.register(InstanceId::new("dev", 0), NodeId(0));
        let _rx = bus.register(InstanceId::new("tester", 0), NodeId(0));
        let devs = bus.instances_of("dev");
        assert_eq!(devs.len(), 2);
        assert_eq!(devs[0].index, 0);
        assert_eq!(bus.all_instances().len(), 3);
    }
}
