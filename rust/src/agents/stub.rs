//! Auto-generated-stub analog: agent method calls that return futures.
//!
//! Paper §3.1: a stub-generation tool turns each declared agent callable
//! into a module whose methods "do not execute the underlying logic;
//! instead, they create and return future objects that encode the call's
//! metadata". [`AgentStub::call`] is exactly that: it allocates the future
//! cell with Table-3 metadata, routes it (late binding via the shared
//! [`Router`]), registers it in the future table + dependency graph, and
//! hands the call to the executor's component controller — all
//! non-blocking (Op 1).

use std::sync::Arc;

use crate::config::{AgentConfig, DeploymentConfig};
use crate::coordinator::Router;
use crate::error::{Error, Result};
use crate::futures::{DepGraph, FutureCell, FutureHandle, FutureMeta, FutureTable, Value};
use crate::ids::{AgentType, FutureId, IdGen, Location, RequestId, SessionId};
use crate::ingress::routing::RouteHint;
use crate::transport::{Bus, CallMsg, Message};

/// Shared runtime context the stubs operate against (cheap clone).
#[derive(Clone)]
pub struct CallCtx {
    pub session: SessionId,
    pub request: RequestId,
    /// Call-graph depth of the calling frame; stubs stamp `stage+1`.
    pub stage: u32,
    pub bus: Bus,
    pub router: Arc<Router>,
    pub graph: Arc<DepGraph>,
    pub table: Arc<FutureTable>,
    pub ids: Arc<IdGen>,
    pub cfg: Arc<DeploymentConfig>,
    /// The request's JIT-routing hint (DESIGN.md §13): the ingress stamps
    /// its per-dispatch variant decision here and stubs copy it into each
    /// call's args. `None` when routing is off — calls go out unrouted.
    pub route: Option<Arc<RouteHint>>,
}

impl CallCtx {
    /// The stub for `agent` (errors later if the agent is undeclared —
    /// mirrors importing a generated module that doesn't exist).
    pub fn agent(&self, agent: &str) -> AgentStub {
        AgentStub { agent: AgentType::new(agent), ctx: self.clone() }
    }

    /// Child context for a deeper call frame (agent-internal workflows).
    pub fn deeper(&self) -> CallCtx {
        let mut c = self.clone();
        c.stage += 1;
        c
    }

    fn holder(&self) -> Location {
        Location::Driver(self.request)
    }
}

/// The generated-stub analog for one agent type.
pub struct AgentStub {
    agent: AgentType,
    ctx: CallCtx,
}

impl AgentStub {
    /// Invoke `method` — returns a future immediately (Op 1, non-blocking).
    pub fn call(&self, method: &str, args: Value) -> FutureHandle {
        self.call_with(method, args, &[], 0)
    }

    /// Invoke with explicit dependencies (futures whose values feed this
    /// call) and a retry count (drivers bump it on relaunch — LPT signal).
    pub fn call_with(
        &self,
        method: &str,
        args: Value,
        deps: &[FutureId],
        retry_count: u32,
    ) -> FutureHandle {
        // Stamp the front door's freshest routing decision into the call
        // args (a driver fanning out several calls from one poll stamps
        // each with the same decision); the component controller re-checks
        // it against the current quality floor at engine admit. `consume`
        // (not `variant`) so per-variant dispatch counters tick exactly
        // once per issued call.
        let mut args = args;
        if let Some(hint) = &self.ctx.route {
            if let Some((variant, urgent)) = hint.consume() {
                args.insert("variant", variant);
                args.insert("urgent", urgent);
            }
        }
        let id = self.ctx.ids.future();
        let mut meta = FutureMeta::new(
            id,
            self.ctx.session,
            self.ctx.request,
            self.agent.clone(),
            method,
            self.ctx.holder(),
        );
        meta.dependencies = deps.to_vec();
        meta.stage = self.ctx.stage + 1;
        meta.retry_count = retry_count;

        let acfg = self.ctx.cfg.agent(self.agent.as_str());
        if let Some(a) = acfg {
            meta.est_cost = a.profile.base_s
                + a.profile.mean_output_tokens * a.profile.per_output_token_s;
            if !a.methods.is_empty() && !a.methods.iter().any(|m| m == method) {
                let cell = FutureCell::new(meta);
                cell.fail(format!("agent `{}` has no method `{method}`", self.agent));
                return FutureHandle::new(cell, self.ctx.holder());
            }
        }

        let cell = FutureCell::new(meta);
        self.ctx.table.insert(cell.clone());
        self.ctx
            .graph
            .on_create(id, self.ctx.request, deps, self.ctx.stage + 1);

        match self.route_and_send(&cell, args, acfg) {
            Ok(()) => {}
            Err(e) => cell.fail(e.to_string()),
        }
        FutureHandle::new(cell.clone(), self.ctx.holder())
    }

    fn route_and_send(
        &self,
        cell: &Arc<FutureCell>,
        args: Value,
        acfg: Option<&AgentConfig>,
    ) -> Result<()> {
        let pin = acfg
            .map(|a| a.directives.stateful || a.directives.managed_state)
            .unwrap_or(false);
        let instance = self
            .ctx
            .router
            .route(self.ctx.session, self.agent.as_str(), pin)?;
        cell.mark_queued(instance.clone());
        let ok = self.ctx.bus.send(
            &instance,
            Message::Call(CallMsg { cell: cell.clone(), args }),
        );
        if !ok {
            return Err(Error::InstanceKilled(instance));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::LoadMap;
    use crate::ids::{InstanceId, NodeId};
    use crate::json;
    use std::time::Duration;

    fn ctx_with_instance() -> (CallCtx, std::sync::mpsc::Receiver<Message>) {
        let bus = Bus::new(Duration::ZERO);
        let loads = LoadMap::new();
        let inst = InstanceId::new("dev", 0);
        let rx = bus.register(inst.clone(), NodeId(0));
        loads.register(inst);
        let cfg = DeploymentConfig::from_json(
            r#"{"agents": [{"name": "dev", "kind": "llm", "methods": ["implement"]}]}"#,
        )
        .unwrap();
        let ctx = CallCtx {
            session: SessionId(1),
            request: RequestId(2),
            stage: 0,
            bus: bus.clone(),
            router: Arc::new(Router::new(bus, loads, 1)),
            graph: Arc::new(DepGraph::new()),
            table: Arc::new(FutureTable::new()),
            ids: Arc::new(IdGen::new()),
            cfg: Arc::new(cfg),
            route: None,
        };
        (ctx, rx)
    }

    #[test]
    fn call_creates_future_and_delivers() {
        let (ctx, rx) = ctx_with_instance();
        let f = ctx.agent("dev").call("implement", json!({"prompt": "x"}));
        assert!(!f.available(), "Op 1 is non-blocking");
        // delivered to the instance inbox with metadata intact
        match rx.try_recv().unwrap() {
            Message::Call(c) => {
                let m = c.cell.meta();
                assert_eq!(m.agent.as_str(), "dev");
                assert_eq!(m.method, "implement");
                assert_eq!(m.stage, 1);
                assert_eq!(m.executor.as_ref().unwrap().to_string(), "dev:0");
                assert_eq!(c.args.get("prompt").as_str(), Some("x"));
            }
            _ => panic!(),
        }
        assert_eq!(ctx.table.len(), 1);
        assert_eq!(ctx.graph.len(), 1);
    }

    #[test]
    fn unknown_agent_fails_future_not_panics() {
        let (ctx, _rx) = ctx_with_instance();
        let f = ctx.agent("ghost").call("x", json!({}));
        assert!(f.available());
        assert!(f.try_value().unwrap().is_err());
    }

    #[test]
    fn undeclared_method_fails() {
        let (ctx, _rx) = ctx_with_instance();
        let f = ctx.agent("dev").call("not_a_method", json!({}));
        assert!(matches!(f.try_value(), Some(Err(_))));
    }

    #[test]
    fn routing_hint_stamps_call_args() {
        use crate::config::ModelVariant;
        use crate::ingress::routing::{Decision, RouteHint, RouteMode, RouteState};
        let (mut ctx, rx) = ctx_with_instance();
        let variants = vec![
            ModelVariant { name: "fast".into(), latency_mult: 0.35, quality: 0.82 },
            ModelVariant { name: "base".into(), latency_mult: 1.0, quality: 0.92 },
        ];
        let rs = RouteState::new(RouteMode::Jit, &variants).unwrap();
        let hint = RouteHint::new(rs);
        hint.set(Decision { variant: 0, urgent: true });
        ctx.route = Some(hint);
        ctx.agent("dev").call("implement", json!({"prompt": "x"}));
        match rx.try_recv().unwrap() {
            Message::Call(c) => {
                assert_eq!(c.args.get("variant").as_str(), Some("fast"));
                assert_eq!(c.args.get("urgent").as_bool(), Some(true));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn deps_and_stage_recorded() {
        let (ctx, _rx) = ctx_with_instance();
        let f1 = ctx.agent("dev").call("implement", json!({}));
        let deeper = ctx.deeper();
        let f2 = deeper
            .agent("dev")
            .call_with("implement", json!({}), &[f1.id()], 2);
        let m = f2.meta();
        assert_eq!(m.dependencies, vec![f1.id()]);
        assert_eq!(m.stage, 2);
        assert_eq!(m.retry_count, 2);
        assert_eq!(ctx.graph.dependents(f1.id()), vec![f2.id()]);
        assert!(m.est_cost > 0.0);
    }
}
