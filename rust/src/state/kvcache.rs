//! Tiered K,V-cache manager with policy hooks — the LMCache substitute.
//!
//! Paper §4.3.2: LLM engines (vLLM/SGLang) manage KV caches with generic
//! heuristics (prefix caching + LRU) because no one tells them which
//! sessions will return. NALAR *does* know — it tracks futures and pending
//! work — so it extends the cache layer with hooks the global controller
//! drives:
//!
//! * `hint_retain` — this session's cache is about to be reused; keep it.
//! * `hint_release` — session ended; the cache is immediately evictable.
//! * `offload` / `migrate_out`+`migrate_in` — explicit placement control,
//!   which is what frees NALAR from session-sticky routing (Fig. 9a).
//!
//! Three tiers model the memory hierarchy: device HBM (fast, scarce),
//! host DRAM (offload target), and Far (remote/disk; effectively a
//! recompute-or-slow-fetch tier). Transfer costs come from a bandwidth
//! model so benches see realistic penalties.

use std::collections::HashMap;

use std::sync::Mutex;

use crate::ids::SessionId;

/// Cache residency tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    DeviceHbm,
    HostDram,
    Far,
}

/// Eviction policy: the paper's baseline vs NALAR's hint-driven policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvPolicy {
    /// Generic LRU (what vLLM/SGLang do absent workflow knowledge).
    Lru,
    /// NALAR: never evict sessions with a live retain hint if avoidable;
    /// prefer evicting released sessions first, then LRU among the rest.
    HintDriven,
}

#[derive(Debug, Clone)]
struct KvEntry {
    bytes: u64,
    seq_len: u32,
    tier: Tier,
    last_used_us: u64,
    /// Global-controller hint: pending/imminent reuse.
    retain: bool,
    /// Session explicitly finished; evict first.
    released: bool,
}

/// Outcome of an HBM residency request, with the modeled cost.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Residency {
    /// Already in HBM.
    Hit,
    /// Promoted from a colder tier; pay the transfer time.
    Promoted { from: Tier, transfer_us: u64 },
    /// Not cached anywhere — the engine must re-prefill (recompute).
    Miss,
}

#[derive(Debug, Default, Clone, Copy)]
pub struct KvStats {
    pub hits: u64,
    pub promotions: u64,
    pub misses: u64,
    pub evictions: u64,
    pub offloads: u64,
    pub hinted_evictions_avoided: u64,
    pub hbm_used: u64,
    pub dram_used: u64,
}

/// Per-LLM-instance cache manager (the "GPU" view), with a host tier.
pub struct KvCacheManager {
    inner: Mutex<Inner>,
    hbm_capacity: u64,
    dram_capacity: u64,
    policy: KvPolicy,
    /// Bandwidths in bytes/us (defaults ~ 20 GB/s HBM<->DRAM, 2 GB/s far).
    dram_bw: f64,
    far_bw: f64,
}

struct Inner {
    entries: HashMap<SessionId, KvEntry>,
    stats: KvStats,
    clock_us: u64,
}

impl KvCacheManager {
    pub fn new(hbm_capacity: u64, dram_capacity: u64, policy: KvPolicy) -> Self {
        KvCacheManager {
            inner: Mutex::new(Inner {
                entries: HashMap::new(),
                stats: KvStats::default(),
                clock_us: 1,
            }),
            hbm_capacity,
            dram_capacity,
            policy,
            dram_bw: 20_000.0, // bytes per microsecond = 20 GB/s
            far_bw: 2_000.0,
        }
    }

    pub fn policy(&self) -> KvPolicy {
        self.policy
    }

    fn used(entries: &HashMap<SessionId, KvEntry>, tier: Tier) -> u64 {
        entries.values().filter(|e| e.tier == tier).map(|e| e.bytes).sum()
    }

    /// Request `bytes` of KV for `session` resident in HBM, evicting /
    /// offloading colder sessions as needed. The returned cost is what the
    /// engine adds to the request's service time.
    pub fn ensure_resident(&self, session: SessionId, bytes: u64, seq_len: u32) -> Residency {
        let mut g = self.inner.lock().unwrap();
        g.clock_us += 1;
        let now = g.clock_us;

        let existing = g.entries.get(&session).map(|e| (e.tier, e.bytes));
        let outcome = match existing {
            Some((Tier::DeviceHbm, _)) => {
                g.stats.hits += 1;
                Residency::Hit
            }
            Some((from @ (Tier::HostDram | Tier::Far), b)) => {
                let bw = if from == Tier::HostDram { self.dram_bw } else { self.far_bw };
                g.stats.promotions += 1;
                Residency::Promoted { from, transfer_us: (b as f64 / bw) as u64 }
            }
            None => {
                g.stats.misses += 1;
                Residency::Miss
            }
        };

        // Make room in HBM, then install/refresh the entry.
        self.make_room_locked(&mut g, bytes, session);
        let entry = g.entries.entry(session).or_insert(KvEntry {
            bytes,
            seq_len,
            tier: Tier::DeviceHbm,
            last_used_us: now,
            retain: false,
            released: false,
        });
        entry.tier = Tier::DeviceHbm;
        entry.bytes = bytes.max(entry.bytes);
        entry.seq_len = seq_len.max(entry.seq_len);
        entry.last_used_us = now;
        entry.released = false;
        g.stats.hbm_used = Self::used(&g.entries, Tier::DeviceHbm);
        g.stats.dram_used = Self::used(&g.entries, Tier::HostDram);
        outcome
    }

    /// Demote victims until `need` fits in HBM. Victim order depends on the
    /// policy; the protected `session` is never selected.
    fn make_room_locked(&self, g: &mut Inner, need: u64, protect: SessionId) {
        loop {
            let used = Self::used(&g.entries, Tier::DeviceHbm);
            if used + need <= self.hbm_capacity {
                return;
            }
            let victim = {
                let candidates = g
                    .entries
                    .iter()
                    .filter(|(s, e)| **s != protect && e.tier == Tier::DeviceHbm);
                match self.policy {
                    KvPolicy::Lru => {
                        candidates.min_by_key(|(_, e)| e.last_used_us).map(|(s, _)| *s)
                    }
                    KvPolicy::HintDriven => candidates
                        .min_by_key(|(_, e)| {
                            // released first, then un-retained LRU, retained last
                            let class = if e.released { 0u64 } else if !e.retain { 1 } else { 2 };
                            (class, e.last_used_us)
                        })
                        .map(|(s, _)| *s),
                }
            };
            let Some(victim) = victim else { return }; // nothing evictable
            if self.policy == KvPolicy::HintDriven && !g.entries[&victim].retain {
                // a retained session survived because a colder victim existed
                if g.entries.values().any(|e| e.tier == Tier::DeviceHbm && e.retain) {
                    g.stats.hinted_evictions_avoided += 1;
                }
            }
            let dram_used = Self::used(&g.entries, Tier::HostDram);
            let e = g.entries.get_mut(&victim).unwrap();
            if dram_used + e.bytes <= self.dram_capacity {
                e.tier = Tier::HostDram;
                g.stats.offloads += 1;
            } else {
                e.tier = Tier::Far;
                g.stats.evictions += 1;
            }
        }
    }

    // ---------------------------------------------------------- hint hooks
    pub fn hint_retain(&self, session: SessionId) {
        let mut g = self.inner.lock().unwrap();
        if let Some(e) = g.entries.get_mut(&session) {
            e.retain = true;
            e.released = false;
        }
    }

    pub fn hint_release(&self, session: SessionId) {
        let mut g = self.inner.lock().unwrap();
        if let Some(e) = g.entries.get_mut(&session) {
            e.retain = false;
            e.released = true;
        }
    }

    /// Push a session's cache out of HBM proactively (policy `offload`).
    pub fn offload(&self, session: SessionId) -> bool {
        let mut g = self.inner.lock().unwrap();
        let dram_used = Self::used(&g.entries, Tier::HostDram);
        let dram_cap = self.dram_capacity;
        if let Some(e) = g.entries.get_mut(&session) {
            if e.tier == Tier::DeviceHbm {
                e.tier = if dram_used + e.bytes <= dram_cap { Tier::HostDram } else { Tier::Far };
                g.stats.offloads += 1;
                g.stats.hbm_used = Self::used(&g.entries, Tier::DeviceHbm);
                return true;
            }
        }
        false
    }

    // ----------------------------------------------------------- migration
    /// Remove the session's cache for transfer to another instance.
    /// Returns `(bytes, seq_len, transfer_us)`.
    pub fn migrate_out(&self, session: SessionId) -> Option<(u64, u32, u64)> {
        let mut g = self.inner.lock().unwrap();
        let e = g.entries.remove(&session)?;
        let bw = match e.tier {
            Tier::DeviceHbm | Tier::HostDram => self.dram_bw,
            Tier::Far => self.far_bw,
        };
        g.stats.hbm_used = Self::used(&g.entries, Tier::DeviceHbm);
        Some((e.bytes, e.seq_len, (e.bytes as f64 / bw) as u64))
    }

    /// Install a migrated-in cache (lands in HBM, evicting as needed).
    pub fn migrate_in(&self, session: SessionId, bytes: u64, seq_len: u32) {
        let mut g = self.inner.lock().unwrap();
        g.clock_us += 1;
        let now = g.clock_us;
        self.make_room_locked(&mut g, bytes, session);
        g.entries.insert(
            session,
            KvEntry {
                bytes,
                seq_len,
                tier: Tier::DeviceHbm,
                last_used_us: now,
                retain: false,
                released: false,
            },
        );
        g.stats.hbm_used = Self::used(&g.entries, Tier::DeviceHbm);
    }

    pub fn drop_session(&self, session: SessionId) -> bool {
        let mut g = self.inner.lock().unwrap();
        let removed = g.entries.remove(&session).is_some();
        g.stats.hbm_used = Self::used(&g.entries, Tier::DeviceHbm);
        removed
    }

    pub fn tier_of(&self, session: SessionId) -> Option<Tier> {
        self.inner.lock().unwrap().entries.get(&session).map(|e| e.tier)
    }

    pub fn stats(&self) -> KvStats {
        let g = self.inner.lock().unwrap();
        let mut s = g.stats;
        s.hbm_used = Self::used(&g.entries, Tier::DeviceHbm);
        s.dram_used = Self::used(&g.entries, Tier::HostDram);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: u64 = 1 << 20;

    #[test]
    fn hit_promote_miss() {
        let m = KvCacheManager::new(10 * MB, 100 * MB, KvPolicy::Lru);
        assert_eq!(m.ensure_resident(SessionId(1), MB, 10), Residency::Miss);
        assert_eq!(m.ensure_resident(SessionId(1), MB, 10), Residency::Hit);
        assert!(m.offload(SessionId(1)));
        match m.ensure_resident(SessionId(1), MB, 10) {
            Residency::Promoted { from: Tier::HostDram, transfer_us } => {
                assert!(transfer_us > 0)
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn lru_evicts_oldest() {
        let m = KvCacheManager::new(3 * MB, 100 * MB, KvPolicy::Lru);
        m.ensure_resident(SessionId(1), MB, 1);
        m.ensure_resident(SessionId(2), MB, 1);
        m.ensure_resident(SessionId(3), MB, 1);
        m.ensure_resident(SessionId(1), MB, 1); // refresh 1 → LRU victim is 2
        m.ensure_resident(SessionId(4), MB, 1);
        assert_eq!(m.tier_of(SessionId(2)), Some(Tier::HostDram));
        assert_eq!(m.tier_of(SessionId(1)), Some(Tier::DeviceHbm));
    }

    #[test]
    fn hints_protect_imminent_reuse() {
        let m = KvCacheManager::new(3 * MB, 100 * MB, KvPolicy::HintDriven);
        m.ensure_resident(SessionId(1), MB, 1);
        m.ensure_resident(SessionId(2), MB, 1);
        m.ensure_resident(SessionId(3), MB, 1);
        // LRU would evict 1; the retain hint redirects eviction to 2.
        m.hint_retain(SessionId(1));
        m.ensure_resident(SessionId(4), MB, 1);
        assert_eq!(m.tier_of(SessionId(1)), Some(Tier::DeviceHbm));
        assert_ne!(m.tier_of(SessionId(2)), Some(Tier::DeviceHbm));
    }

    #[test]
    fn released_evicted_first() {
        let m = KvCacheManager::new(3 * MB, 100 * MB, KvPolicy::HintDriven);
        m.ensure_resident(SessionId(1), MB, 1);
        m.ensure_resident(SessionId(2), MB, 1);
        m.ensure_resident(SessionId(3), MB, 1);
        m.hint_release(SessionId(3)); // newest but finished
        m.ensure_resident(SessionId(4), MB, 1);
        assert_ne!(m.tier_of(SessionId(3)), Some(Tier::DeviceHbm));
        assert_eq!(m.tier_of(SessionId(1)), Some(Tier::DeviceHbm));
    }

    #[test]
    fn migration_roundtrip() {
        let src = KvCacheManager::new(10 * MB, 100 * MB, KvPolicy::HintDriven);
        let dst = KvCacheManager::new(10 * MB, 100 * MB, KvPolicy::HintDriven);
        src.ensure_resident(SessionId(7), 2 * MB, 64);
        let (bytes, seq, cost) = src.migrate_out(SessionId(7)).unwrap();
        assert_eq!(bytes, 2 * MB);
        assert_eq!(seq, 64);
        assert!(cost > 0);
        assert!(src.tier_of(SessionId(7)).is_none());
        dst.migrate_in(SessionId(7), bytes, seq);
        assert_eq!(dst.tier_of(SessionId(7)), Some(Tier::DeviceHbm));
        assert_eq!(dst.ensure_resident(SessionId(7), bytes, seq), Residency::Hit);
    }

    #[test]
    fn dram_overflow_goes_far() {
        let m = KvCacheManager::new(MB, MB, KvPolicy::Lru);
        m.ensure_resident(SessionId(1), MB, 1);
        m.ensure_resident(SessionId(2), MB, 1); // 1 → DRAM
        m.ensure_resident(SessionId(3), MB, 1); // 2 → Far (DRAM full)
        let tiers: Vec<_> = [1, 2, 3]
            .iter()
            .map(|&s| m.tier_of(SessionId(s)).unwrap())
            .collect();
        assert!(tiers.contains(&Tier::Far));
        assert_eq!(m.tier_of(SessionId(3)), Some(Tier::DeviceHbm));
    }

    #[test]
    fn stats_track() {
        let m = KvCacheManager::new(10 * MB, 100 * MB, KvPolicy::Lru);
        m.ensure_resident(SessionId(1), MB, 1);
        m.ensure_resident(SessionId(1), MB, 1);
        let s = m.stats();
        assert_eq!(s.misses, 1);
        assert_eq!(s.hits, 1);
        assert_eq!(s.hbm_used, MB);
    }
}
