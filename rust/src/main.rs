//! `nalar` CLI: launch deployments, run workloads, reproduce the paper.
//!
//! ```text
//! nalar run    --workflow financial|router|swe --system nalar|ayo|crew|autogen
//!              [--rps 8] [--secs 5] [--config path.json]
//! nalar info   [--config path.json]      # validate + describe a deployment
//! nalar bench  [--quick] [--only fig9,fig10,table4,sec62] [--out DIR]
//!              [--check-only]            # writes/validates BENCH_*.json
//! ```

use std::path::PathBuf;
use std::time::Duration;

use nalar::baselines::SystemUnderTest;
use nalar::bench::{self, BenchOpts};
use nalar::config::DeploymentConfig;
use nalar::server::Deployment;
use nalar::util::cli::Args;
use nalar::workflow::{run_open_loop, RunConfig, WorkflowKind};

fn parse_system(s: &str) -> SystemUnderTest {
    match s {
        "ayo" => SystemUnderTest::AyoLike,
        "crew" => SystemUnderTest::CrewLike,
        "autogen" => SystemUnderTest::AutoGenLike,
        _ => SystemUnderTest::Nalar,
    }
}

fn parse_workflow(s: &str) -> WorkflowKind {
    match s {
        "router" => WorkflowKind::Router,
        "swe" => WorkflowKind::Swe,
        _ => WorkflowKind::Financial,
    }
}

fn main() -> nalar::Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&args),
        Some("info") => cmd_info(&args),
        Some("bench") => cmd_bench(&args),
        _ => {
            eprintln!(
                "usage: nalar <run|info|bench> [--workflow financial|router|swe] \
                 [--system nalar|ayo|crew|autogen] [--rps N] [--secs N] [--config file.json] \
                 | bench [--quick] [--only fig9,fig10,table4,sec62] [--out DIR] [--check-only]"
            );
            Ok(())
        }
    }
}

fn load_config(args: &Args, wf: WorkflowKind) -> nalar::Result<DeploymentConfig> {
    Ok(match args.get("config") {
        Some(path) => DeploymentConfig::from_json_file(path)?,
        None => wf.config(),
    })
}

fn cmd_run(args: &Args) -> nalar::Result<()> {
    let wf = parse_workflow(&args.str_or("workflow", "financial"));
    let system = parse_system(&args.str_or("system", "nalar"));
    let cfg = load_config(args, wf)?;
    let scale = cfg.time_scale;
    let d = Deployment::launch_as(cfg, system)?;
    let rc = RunConfig {
        workflow: wf,
        rps: args.f64_or("rps", 8.0),
        duration: Duration::from_secs(args.u64_or("secs", 5)),
        session_pool: args.usize_or("sessions", 32),
        request_timeout: Duration::from_secs(args.u64_or("timeout", 60)),
        seed: args.u64_or("seed", 7),
    };
    println!(
        "running {} on {} at {} wall-RPS for {:?} (time_scale {})",
        wf.name(),
        system.name(),
        rc.rps,
        rc.duration,
        scale
    );
    let (stats, rec) = run_open_loop(&d, &rc);
    let paper = rec.summary_scaled(1.0 / stats.time_scale);
    println!(
        "completed {} failed {} | paper-s avg {:.1} p50 {:.1} p95 {:.1} p99 {:.1} | imbalance {:.2}x",
        stats.completed, stats.failed, paper.avg, paper.p50, paper.p95, paper.p99, stats.imbalance
    );
    d.shutdown();
    Ok(())
}

fn cmd_info(args: &Args) -> nalar::Result<()> {
    let wf = parse_workflow(&args.str_or("workflow", "financial"));
    let cfg = load_config(args, wf)?;
    println!("nodes: {}  time_scale: {}  policies: {:?}", cfg.nodes, cfg.time_scale, cfg.policies);
    for a in &cfg.agents {
        println!(
            "  {:<16} {:?} x{}  stateful={} batchable={} managed_state={} max={}",
            a.name,
            a.kind,
            a.instances,
            a.directives.stateful,
            a.directives.batchable,
            a.directives.managed_state,
            a.directives.max_instances
        );
    }
    Ok(())
}

/// `nalar bench`: the one-command reproduction of the paper's numbers
/// (Fig. 9, Fig. 10, Table 4, §6.2), emitting schema-validated
/// `BENCH_*.json` reports. `--quick` is the CI-smoke profile.
fn cmd_bench(args: &Args) -> nalar::Result<()> {
    let out_dir = PathBuf::from(args.str_or("out", "."));
    let only: Option<Vec<String>> = args
        .get("only")
        .map(|s| s.split(',').map(|p| p.trim().to_string()).collect());
    if args.flag("check-only") {
        let names: Vec<&str> = match &only {
            Some(list) => list.iter().map(|s| s.as_str()).collect(),
            None => bench::ALL.to_vec(),
        };
        return bench::check_files(&out_dir, &names);
    }
    let opts = BenchOpts {
        quick: args.flag("quick") || std::env::var("NALAR_BENCH_QUICK").is_ok(),
        out_dir,
        only,
    };
    let written = bench::run(&opts)?;
    println!("bench reports written:");
    for p in written {
        println!("  {}", p.display());
    }
    Ok(())
}
