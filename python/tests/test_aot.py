"""AOT path: manifest integrity + HLO text round-trip sanity.

Full numerics of the artifact (HLO executed through PJRT vs the jax model)
are validated on the Rust side (rust/tests/runtime_numerics.rs); here we
check the build outputs are structurally sound without re-lowering.
"""

import json
import pathlib

import numpy as np
import pytest

from compile.aot import DECODE_BATCHES, EMBED_BATCHES, PREFILL_BATCHES
from compile.model import ModelConfig, param_spec

ART = pathlib.Path(__file__).resolve().parents[2] / "artifacts"

pytestmark = pytest.mark.skipif(
    not (ART / "manifest.json").exists(), reason="run `make artifacts` first"
)


@pytest.fixture(scope="module")
def manifest():
    return json.loads((ART / "manifest.json").read_text())


def test_manifest_lists_all_variants(manifest):
    names = {e["name"] for e in manifest["entries"]}
    for b in PREFILL_BATCHES:
        assert f"prefill_b{b}" in names
    for b in DECODE_BATCHES:
        assert f"decode_b{b}" in names
    for b in EMBED_BATCHES:
        assert f"embed_b{b}" in names


def test_hlo_files_exist_and_parse_shape(manifest):
    for e in manifest["entries"]:
        text = (ART / e["file"]).read_text()
        assert "ENTRY" in text and "HloModule" in text
        # every data input shape should appear in the entry signature
        for di in e["data_inputs"]:
            dims = "," .join(str(d) for d in di["shape"])
            token = f"{'s32' if di['dtype']=='i32' else 'f32'}[{dims}]"
            assert token in text, f"{e['name']}: missing {token}"


def test_params_bin_matches_layout(manifest):
    blob = np.fromfile(ART / manifest["params_file"], np.float32)
    assert blob.size == manifest["param_count"]
    total = sum(p["len"] for p in manifest["params"])
    assert total == blob.size
    # layout offsets are contiguous and ordered like param_spec
    cfg = ModelConfig()
    spec_names = [n for n, _ in param_spec(cfg)]
    assert [p["name"] for p in manifest["params"]] == spec_names
    off = 0
    for p in manifest["params"]:
        assert p["offset"] == off
        assert p["len"] == int(np.prod(p["shape"]))
        off += p["len"]


def test_model_config_roundtrip(manifest):
    cfg = ModelConfig()
    m = manifest["model"]
    assert m["vocab"] == cfg.vocab
    assert m["max_seq"] == cfg.max_seq
    assert m["pad"] == cfg.PAD


def test_weights_finite_and_nontrivial(manifest):
    blob = np.fromfile(ART / manifest["params_file"], np.float32)
    assert np.all(np.isfinite(blob))
    assert blob.std() > 0.01  # not all zeros
