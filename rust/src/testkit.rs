//! Property-testing kit (proptest substitute, offline build).
//!
//! Runs a property against many generated cases from a deterministic seed;
//! on failure it reports the seed + case index so the exact counterexample
//! replays with `NALAR_PROP_SEED=<seed>`. A light "shrink" retries the
//! failing generator with progressively smaller size hints.

use crate::util::rng::Rng;

/// Number of cases per property (override with NALAR_PROP_CASES).
pub fn default_cases() -> usize {
    std::env::var("NALAR_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(128)
}

fn base_seed() -> u64 {
    std::env::var("NALAR_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xA11CE)
}

/// Size hint passed to generators: grows with the case index so early
/// cases are small (cheap, debuggable) and later cases stress harder.
#[derive(Clone, Copy, Debug)]
pub struct Size(pub usize);

/// Check `prop` on `cases` generated inputs. Panics with a replayable
/// message on the first failure.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    gen: impl Fn(&mut Rng, Size) -> T,
    prop: impl FnMut(&T) -> bool,
) {
    check_n(name, default_cases(), gen, prop)
}

pub fn check_n<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    gen: impl Fn(&mut Rng, Size) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let seed = base_seed();
    for case in 0..cases {
        let mut rng = Rng::new(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let size = Size(1 + case * 64 / cases.max(1));
        let input = gen(&mut rng, size);
        if !prop(&input) {
            // shrink: retry smaller sizes with the same stream
            let mut smallest = format!("{input:?}");
            for s in (0..size.0).rev() {
                let mut r2 = Rng::new(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
                let candidate = gen(&mut r2, Size(s));
                if !prop(&candidate) {
                    smallest = format!("{candidate:?}");
                }
            }
            panic!(
                "property `{name}` failed at case {case} (NALAR_PROP_SEED={seed}).\n\
                 counterexample: {smallest}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("reverse-roundtrip", |r, s| {
            (0..s.0 + 1).map(|_| r.next_u64()).collect::<Vec<_>>()
        }, |v| {
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            w == *v
        });
    }

    #[test]
    #[should_panic(expected = "property `always-false` failed")]
    fn failing_property_reports() {
        check_n("always-false", 4, |r, _| r.next_u64(), |_| false);
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first = Vec::new();
        check_n("capture", 3, |r, s| {
            let v = (r.next_u64(), s.0);
            v
        }, |v| {
            first.push(*v);
            true
        });
        let mut second = Vec::new();
        check_n("capture", 3, |r, s| (r.next_u64(), s.0), |v| {
            second.push(*v);
            true
        });
        assert_eq!(first, second);
    }
}
